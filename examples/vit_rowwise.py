"""The paper's own workload: Swin on the row-wise primitives + the ASIC
reproduction report (Tables III/IV, Fig. 2).

Run:  PYTHONPATH=src python examples/vit_rowwise.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.swin_t import CONFIG as SWIN_T, reduced
from repro.core.asic_model import ASIC, run_asic, swin_ops, swin_params
from repro.core.rowwise import schedule_model
from repro.models import vision


def main():
    # 1. Faithful reproduction: walk Swin-T through the ASIC cycle model.
    rep = run_asic(swin_ops(SWIN_T))
    print("=== paper reproduction (TSMC 40nm ASIC model) ===")
    print(f"peak throughput : {ASIC.peak_gops:.1f} GOPS "
          f"(paper: 403.2)")
    print(f"swin-t latency  : {rep.time_s*1e3:.2f} ms (paper: ~22.4)")
    print(f"swin-t images/s : {rep.images_per_s:.1f} (paper: 44.5)")
    print(f"utilization     : {rep.utilization:.4f} (paper: ~0.99)")
    shares = rep.flops_shares()
    p = swin_params(SWIN_T)
    pt = sum(p.values())
    print(f"Fig.2 FLOPs     : fc={shares['fc']:.3f} "
          f"conv={shares['conv']:.3f} attn={shares['attn']:.3f}")
    print(f"Fig.2 params    : fc={p['fc']/pt:.3f}")

    # 2. The same GEMMs under the TPU row-wise schedule.
    sched = schedule_model(swin_ops(SWIN_T))
    print("\n=== TPU v5e row-wise schedule (same GEMM walk) ===")
    print(f"utilization     : {sched.utilization:.3f} "
          "(small ViT GEMMs pad against 128-wide MXU tiles; the ASIC's "
          "4-wide rows fit them exactly — see EXPERIMENTS.md)")

    # 3. Run a reduced Swin end-to-end through the row-wise kernels.
    cfg = reduced()
    key = jax.random.PRNGKey(0)
    params = vision.init_swin(key, cfg)
    img = jax.random.normal(key, (8, cfg.img_size, cfg.img_size, 3))
    fwd = jax.jit(lambda p, x: vision.swin_forward(p, x, cfg))
    logits = jax.block_until_ready(fwd(params, img))
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(fwd(params, img))
    dt = (time.perf_counter() - t0) / 3
    print(f"\nswin-smoke fwd on this host: {logits.shape}, "
          f"{8/dt:.1f} img/s")
    assert bool(jnp.all(jnp.isfinite(logits)))


if __name__ == "__main__":
    main()
