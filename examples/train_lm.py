"""End-to-end training driver example: trains a ~100M-param llama-style
model (or a CPU-sized preset) for a few hundred steps with
checkpointing and exact resume.

  PYTHONPATH=src python examples/train_lm.py                # CPU preset
  PYTHONPATH=src python examples/train_lm.py --preset 100m  # full-size
"""
import argparse

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu", choices=["cpu", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.preset == "100m":
        # ~100M params: deepseek-family dims scaled down
        import repro.configs.deepseek_7b as ds
        from repro.core.types import ModelConfig
        cfg = ModelConfig(name="llama-100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=12,
                          d_ff=2048, vocab=32000, act="silu", norm="rms")
        # register ad hoc and launch through the driver machinery
        from repro import configs
        configs.ARCHS["llama-100m"] = cfg
        train_driver.main(["--arch", "llama-100m",
                           "--steps", str(args.steps),
                           "--batch", "8", "--seq", "512",
                           "--ckpt-dir", args.ckpt_dir])
    else:
        train_driver.main(["--arch", "deepseek-7b", "--smoke",
                           "--steps", str(args.steps),
                           "--batch", "8", "--seq", "128",
                           "--ckpt-dir", args.ckpt_dir])


if __name__ == "__main__":
    main()
