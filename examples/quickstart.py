"""Quickstart: the row-wise primitive, int8 mode, and a tiny LM step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.quant import quantize_per_channel, quantize_per_row
from repro.core.rowwise import plan_matmul
from repro.core.types import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.kernels import ops
from repro.models import lm
from repro.train import step as tsl


def main():
    key = jax.random.PRNGKey(0)

    # 1. The paper's dot-product primitive: plan + execute a matmul.
    plan = plan_matmul(3136, 96, 288)          # a Swin-T FC layer
    print(f"row-wise plan: bm={plan.bm} bk={plan.bk} bn={plan.bn} "
          f"grid={plan.grid} util={plan.utilization:.3f} "
          f"vmem={plan.vmem_bytes/1e6:.1f}MB")
    x = jax.random.normal(key, (3136, 96))
    w = jax.random.normal(key, (96, 288))
    y = ops.matmul(x, w, activation="gelu")
    print("matmul+gelu:", y.shape, y.dtype)

    # 2. 8-bit weights/activations (the paper's precision).
    xq, xs = quantize_per_row(x)
    wq, ws = quantize_per_channel(w)
    y8 = ops.matmul_int8(xq, wq, xs, ws)
    err = jnp.max(jnp.abs(y8 - x @ w)) / jnp.max(jnp.abs(x @ w))
    print(f"int8 W8A8 relative error: {float(err):.4f}")

    # 3. A tiny LM: three train steps on the synthetic pipeline.
    cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab=64, act="silu", norm="rms")
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    tcfg = tsl.TrainConfig(remat=False, total_steps=100)
    state = tsl.init_state(params, tcfg)
    step = jax.jit(tsl.make_train_step(cfg, tcfg))
    ds = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=4))
    for i in range(3):
        state, m = step(state, jax.tree.map(jnp.asarray, ds.batch(i)))
        print(f"step {i}: loss={float(m['loss']):.4f} "
              f"acc={float(m['accuracy']):.3f}")


if __name__ == "__main__":
    main()
