"""Serving example: paged-KV continuous batching over a mixed request
stream (bucketed prefill, block-table decode, page reclamation).

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve as serve_driver


def main():
    serve_driver.main(["--arch", "deepseek-7b", "--smoke",
                       "--requests", "10", "--slots", "4",
                       "--max-new", "12", "--page-size", "16"])


if __name__ == "__main__":
    main()
