"""Distribution tests: each scenario runs in a subprocess with 8 host
devices (XLA_FLAGS is process-global, so tests keep their own 1-device
world per the brief)."""
import os
import subprocess
import sys

import pytest

_WORKER = os.path.join(os.path.dirname(__file__), "dist_worker.py")
_SCENARIOS = ["fsdp_matches_single", "moe_ep_matches_local",
              "compressed_pods_close", "elastic_restore",
              "seq_sharded_decode", "dryrun_small"]


@pytest.mark.parametrize("scenario", _SCENARIOS)
def test_dist_scenario(scenario):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "src")
    proc = subprocess.run(
        [sys.executable, _WORKER, scenario],
        capture_output=True, text=True, timeout=560, env=env)
    assert proc.returncode == 0, (
        f"{scenario} failed:\nSTDOUT:\n{proc.stdout[-4000:]}\n"
        f"STDERR:\n{proc.stderr[-4000:]}")
