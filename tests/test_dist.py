"""Distribution tests: each scenario runs in a subprocess with 8 host
devices (XLA_FLAGS is process-global, so tests keep their own 1-device
world per the brief)."""
import os
import subprocess
import sys

import jax
import pytest

pytestmark = [pytest.mark.slow, pytest.mark.dist]  # subprocess 8-device worlds

# jax 0.4.x shard_map (experimental) rejects inner GSPMD sharding
# constraints that name a manual axis; the pod-compression step relies
# on that mix. jax >= 0.5 (top-level jax.shard_map) handles it, but is
# outside the currently pinned support range — so under the pin this
# scenario always xfails.
_OLD_SHARD_MAP = not hasattr(jax, "shard_map")

_WORKER = os.path.join(os.path.dirname(__file__), "dist_worker.py")
_SCENARIOS = ["fsdp_matches_single", "moe_ep_matches_local",
              "compressed_pods_close", "elastic_restore",
              "seq_sharded_decode", "dryrun_small"]


@pytest.mark.parametrize("scenario", _SCENARIOS)
def test_dist_scenario(scenario):
    if scenario == "compressed_pods_close" and _OLD_SHARD_MAP:
        pytest.xfail("jax<0.5 shard_map can't mix a manual 'pod' axis "
                     "with inner GSPMD constraints naming it")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "src")
    proc = subprocess.run(
        [sys.executable, _WORKER, scenario],
        capture_output=True, text=True, timeout=560, env=env)
    assert proc.returncode == 0, (
        f"{scenario} failed:\nSTDOUT:\n{proc.stdout[-4000:]}\n"
        f"STDERR:\n{proc.stderr[-4000:]}")
