"""Per-kernel allclose vs the pure-jnp oracles, sweeping shapes/dtypes.

Kernels execute via pallas interpret mode (the kernel body runs on CPU);
the same bodies compile for TPU via pl.pallas_call BlockSpecs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.core.quant import quantize_per_channel, quantize_per_row
from repro.core.rowwise import plan_matmul
from repro.kernels import ops, ref
from repro.kernels.rowwise_matmul import rowwise_matmul_p

jax.config.update("jax_enable_x64", False)


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (37, 130, 77), (64, 256, 96),
                                   (1, 96, 13), (130, 48, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rowwise_matmul_shapes(rng, m, k, n, dtype):
    x, w = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
    got = ops.matmul(x, w, impl="interpret")
    want = ref.matmul_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("activation", [None, "gelu", "silu", "relu",
                                        "relu2"])
def test_rowwise_matmul_epilogue(rng, activation):
    x, w = _rand(rng, (24, 64)), _rand(rng, (64, 32))
    b = _rand(rng, (32,))
    got = ops.matmul(x, w, bias=b, activation=activation, impl="interpret")
    want = ref.matmul_ref(x, w, bias=b, activation=activation)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_adder_tree_large_k(rng):
    """K > VMEM panel: the kernel's k grid axis accumulates (Sec. IV-D)."""
    x, w = _rand(rng, (16, 9000)), _rand(rng, (9000, 64))
    got = ops.matmul(x, w, impl="interpret")
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-3)


def test_adder_tree_single_pallas_call(rng):
    """k_splits > 1 must fuse into ONE pallas_call — no Python loop of
    partial-sum kernels round-tripping fp32 partials through HBM."""
    x, w = _rand(rng, (16, 9000)), _rand(rng, (9000, 64))
    plan = plan_matmul(16, 9000, 64, dtype_bytes=4)
    assert plan.k_splits > 1
    jaxpr = jax.make_jaxpr(
        lambda a, b: ops.matmul(a, b, impl="interpret"))(x, w)
    # structured eqn count (repro.analysis), not a string match: a
    # kernel *named* "pallas_call_helper" or a primitive rename must
    # not silently change what this asserts
    assert analysis.count_primitive(jaxpr, "pallas_call") == 1, \
        str(jaxpr)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bias,activation", [(False, None), (True, None),
                                             (True, "gelu"),
                                             (False, "relu")])
@pytest.mark.parametrize("k", [256, 300, 777])
def test_fused_ksplit_parity(rng, dtype, bias, activation, k):
    """Forced k_splits > 1 (tiny k_max) vs ref, incl. K % bk != 0."""
    m, n = 24, 128
    x, w = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
    b = _rand(rng, (n,)) if bias else None
    plan = plan_matmul(m, k, n, dtype_bytes=x.dtype.itemsize, k_max=128)
    assert plan.k_splits > 1 and plan.grid[2] == plan.k_splits
    got = rowwise_matmul_p(x, w, bias=b, activation=activation,
                           plan=plan, interpret=True)
    want = ref.matmul_ref(x, w, bias=b, activation=activation)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("bias", [False, True])
@pytest.mark.parametrize("k", [256, 391])
def test_fused_ksplit_int8(rng, bias, k):
    """int8 adder tree: int32 partials accumulate exactly across the k
    axis, dequant (+bias) epilogue fires once on the last step."""
    m, n = 33, 64
    x, w = _rand(rng, (m, k)), _rand(rng, (k, n))
    xq, xs = quantize_per_row(x)
    wq, ws = quantize_per_channel(w)
    b = _rand(rng, (n,)) if bias else None
    plan = plan_matmul(m, k, n, dtype_bytes=1, k_max=128)
    assert plan.k_splits > 1
    got = rowwise_matmul_p(xq, wq, x_scale=xs.reshape(-1, 1), w_scale=ws,
                           bias=b, activation=None, plan=plan,
                           interpret=True)
    want = ref.matmul_int8_ref(xq, wq, xs.reshape(-1, 1), ws, bias=b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_int8_matmul(rng):
    x, w = _rand(rng, (33, 96)), _rand(rng, (96, 64))
    xq, xs = quantize_per_row(x)
    wq, ws = quantize_per_channel(w)
    got = ops.matmul_int8(xq, wq, xs, ws, impl="interpret")
    want = ref.matmul_int8_ref(xq, wq, xs.reshape(-1, 1), ws)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # int8 quantization error vs fp32 ground truth stays bounded
    err = np.max(np.abs(np.asarray(got) - np.asarray(x @ w)))
    assert err < 0.05 * np.max(np.abs(np.asarray(x @ w)))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("sq,skv,hq,hkv", [(67, 67, 8, 2), (32, 32, 4, 4),
                                           (16, 48, 4, 1)])
def test_flash_attention(rng, causal, window, sq, skv, hq, hkv):
    hd = 32
    q = _rand(rng, (2, hq, sq, hd))
    k = _rand(rng, (2, hkv, skv, hd))
    v = _rand(rng, (2, hkv, skv, hd))
    got = ops.attention(q, k, v, causal=causal, window=window,
                        impl="interpret")
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_q_offset(rng):
    """Chunked prefill: queries starting mid-sequence."""
    hd, sq, skv = 32, 16, 64
    q = _rand(rng, (1, 4, sq, hd))
    k = _rand(rng, (1, 4, skv, hd))
    v = _rand(rng, (1, 4, skv, hd))
    got = ops.attention(q, k, v, causal=True, q_offset=48,
                        impl="interpret")
    want = ref.attention_ref(q, k, v, causal=True, q_offset=48)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kind", ["layer", "rms"])
@pytest.mark.parametrize("m,d", [(7, 64), (256, 96), (33, 128)])
def test_layernorm(rng, kind, m, d):
    x = _rand(rng, (m, d))
    g, b = _rand(rng, (d,)), _rand(rng, (d,))
    beta = b if kind == "layer" else None
    got = ops.layernorm(x, g, beta, kind=kind, impl="interpret")
    want = ref.layernorm_ref(x, g, beta, kind=kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_patch_embed_matches_conv(rng):
    """Conv-as-matmul unification (paper Sec. IV-C) == lax.conv oracle."""
    img = _rand(rng, (2, 16, 16, 3))
    w = _rand(rng, (48, 24))
    got = ops.patch_embed(img, w, patch=4, impl="interpret")
    want = ref.patch_embed_ref(img, w, patch=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
