"""Tensor-parallel placement tests.

Fast host-side tests (permutation algebra, validation, CLI parsing,
traffic model, construction-time rejection) run on a single device.

The parity tests need a real multi-device world: they are marked
``dist`` and run in-process in the CI ``dist`` tier, which exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before pytest
starts (the flag must precede jax initialisation, so it cannot be set
from inside a test). On a single-device world they skip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REDUCED
from repro.core import quant
from repro.core.block_traffic import serve_tp_traffic
from repro.core.types import PagingConfig
from repro.models import lm
from repro.serve.engine import Engine, Request
from repro.serve.placement import (SingleDevice, TensorParallel,
                                   from_mesh_shape, shard_perm)

# ---------------------------------------------------------------- fast


def test_shard_perm_is_segmentwise():
    widths = (8, 4, 4)
    t = 2
    idx = shard_perm(widths, t)
    assert sorted(idx) == list(range(sum(widths)))
    # label each source column (segment, position); after permutation a
    # plain t-way split must hand shard i segment-s columns
    # [i*w/t, (i+1)*w/t) for every segment, in segment order
    labels = [(s, c) for s, w in enumerate(widths) for c in range(w)]
    permuted = [labels[i] for i in idx]
    per = len(idx) // t
    for i in range(t):
        shard = permuted[i * per:(i + 1) * per]
        want = [(s, c) for s, w in enumerate(widths)
                for c in range(i * w // t, (i + 1) * w // t)]
        assert shard == want


def test_shard_perm_matmul_equivalence(rng):
    """Permuted-then-split fused panel computes the same projections."""
    widths = (6, 3, 3)
    t = 3
    x = rng.standard_normal((2, 5)).astype(np.float32)
    w = rng.standard_normal((5, sum(widths))).astype(np.float32)
    idx = shard_perm(widths, t)
    wp = w[:, idx]
    full = x @ w
    segs = np.split(full, np.cumsum(widths)[:-1], axis=1)
    per = sum(widths) // t
    for i in range(t):
        local = x @ wp[:, i * per:(i + 1) * per]
        offs = 0
        for s, wdt in enumerate(widths):
            p = wdt // t
            got = local[:, offs:offs + p]
            want = segs[s][:, i * p:(i + 1) * p]
            np.testing.assert_allclose(got, want, rtol=1e-6)
            offs += p


def test_validate_rejects_indivisible_heads():
    cfg = REDUCED["gemma3-27b"]()          # n_kv_heads = 2
    with pytest.raises(ValueError, match="cannot shard"):
        TensorParallel(4).validate(cfg)
    TensorParallel(2).validate(cfg)        # divisible: fine


def test_validate_rejects_non_bucketing_arch():
    cfg = REDUCED["rwkv6-3b"]()
    with pytest.raises(ValueError, match="causal"):
        TensorParallel(2).validate(cfg)


def test_from_mesh_shape_parsing():
    assert isinstance(from_mesh_shape(""), SingleDevice)
    assert isinstance(from_mesh_shape("1"), SingleDevice)
    assert isinstance(from_mesh_shape("model=1"), SingleDevice)
    tp = from_mesh_shape("4")
    assert isinstance(tp, TensorParallel) and tp.n_shards == 4
    tp = from_mesh_shape("model=2")
    assert isinstance(tp, TensorParallel) and tp.n_shards == 2
    with pytest.raises(ValueError, match="axis"):
        from_mesh_shape("data=2")
    with pytest.raises(ValueError):
        from_mesh_shape("banana")
    with pytest.raises(ValueError):
        from_mesh_shape("0")


def test_serve_tp_traffic_model():
    cfg = REDUCED["deepseek-7b"]()
    trace = [[16, 16, 16, 16]] * 10
    kw = dict(n_slots=4, max_len=128, page_size=16)
    t4 = serve_tp_traffic(trace, cfg, tp=4, **kw)
    t2 = serve_tp_traffic(trace, cfg, tp=2, **kw)
    assert t4["single_bytes"] == t2["single_bytes"]
    # sharding must help, monotonically, and the all-reduce term must be
    # priced (nonzero) yet not erase the win
    assert t4["allreduce_bytes"] > 0
    assert t4["per_device_bytes"] < t2["per_device_bytes"]
    assert t2["per_device_bytes"] < t2["single_bytes"]
    assert t4["ratio"] > t2["ratio"] > 1.0
    parts = (t4["kv_bytes"] // 4 + t4["weight_bytes"] // 4
             + t4["lm_head_bytes"] // 4 + t4["allreduce_bytes"])
    assert t4["per_device_bytes"] == parts


def test_engine_rejects_indivisible_mesh_at_construction():
    cfg = REDUCED["gemma3-27b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    # raised by validate() before the mesh (or any device buffer) is
    # built, so it works — and fails fast — on a 1-device world too
    with pytest.raises(ValueError, match="cannot shard"):
        Engine(params, cfg, n_slots=2, max_len=64, eos_id=-1,
               placement=TensorParallel(4))


# ------------------------------------------------- dist (emulated mesh)

PROMPTS = [5, 37, 64, 12, 90, 23, 48, 7]


def _need_devices(n):
    if jax.device_count() < n:
        pytest.skip(f"needs {n} devices (run under XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")


def _greedy_streams(params, cfg, place, *, n_slots=4, max_len=128,
                    chunk=32, max_new=8, prompts=PROMPTS):
    rng = np.random.default_rng(0)
    eng = Engine(params, cfg, n_slots=n_slots, max_len=max_len,
                 eos_id=-1, temperature=0.0,
                 paging=PagingConfig(prefill_chunk=chunk),
                 placement=place)
    for rid, plen in enumerate(prompts):
        prompt = jnp.asarray(rng.integers(2, cfg.vocab, size=(plen,)),
                             jnp.int32)
        eng.submit(Request(rid=rid, prompt=prompt, max_new=max_new))
    done = eng.run()
    counts = eng.compile_counts()
    n_chunk_shapes = len([b for b in eng.buckets if b <= chunk])
    assert (counts["prefill"] + counts["chunk"] + counts["step"]
            <= len(eng.buckets) + n_chunk_shapes + 1), counts
    return {c.rid: c.tokens for c in done}, counts


@pytest.mark.slow
@pytest.mark.dist
@pytest.mark.parametrize("t", [1, 2, 4])
def test_tp_parity_deepseek(t):
    """Greedy streams over a mixed trace (chunked prefill mid-stream)
    are bit-identical to single-device, and the compile-count bound
    survives sharding exactly."""
    _need_devices(t)
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    ref, ref_counts = _greedy_streams(params, cfg, SingleDevice())
    got, counts = _greedy_streams(params, cfg, TensorParallel(t))
    assert got == ref
    assert counts == ref_counts


@pytest.mark.slow
@pytest.mark.dist
def test_tp_parity_gemma3_sliding_window():
    """Sliding-window attention + tied embeddings + non-gated MLP: the
    kv-head-sharded pools and replicated unembed stay exact."""
    _need_devices(2)
    cfg = REDUCED["gemma3-27b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    ref, _ = _greedy_streams(params, cfg, SingleDevice())
    got, _ = _greedy_streams(params, cfg, TensorParallel(2))
    assert got == ref


@pytest.mark.slow
@pytest.mark.dist
def test_tp_parity_int8_weights():
    """Weight-only int8 panels: per-output-channel scales split with
    column shards and replicate across row shards."""
    _need_devices(4)
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    qparams = quant.quantize_tree(params, quant.lm_weight_predicate)
    ref, _ = _greedy_streams(qparams, cfg, SingleDevice())
    got, _ = _greedy_streams(qparams, cfg, TensorParallel(4))
    assert got == ref
