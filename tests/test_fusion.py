"""Fused block-pipeline parity vs the per-op `ref` composition (PR 2).

Covers the three fusion slots (norm prologue, wide-N multi-projection,
residual/gating epilogues) across bf16/fp32/int8, the flash-attention
score-bias operand, the pallas_call budget per attn+MLP sublayer pair,
and the modeled HBM-traffic win the fusion must deliver.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.core import runtime
from repro.core.block_traffic import swin_block_traffic, swin_t_stage_cases
from repro.core.quant import quantize_per_channel, quantize_per_row
from repro.core.rowwise import plan_matmul
from repro.core.types import BlockDef, ModelConfig
from repro.kernels import ops, ref
from repro.kernels.rowwise_matmul import rowwise_matmul_p
from repro.models import attention, blocks

jax.config.update("jax_enable_x64", False)

DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


def _tols(dtype):
    return (1e-5, 8e-5) if dtype == jnp.float32 else (2e-2, 1.6e-1)


def _close(got, want, dtype):
    rtol, atol = _tols(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)


# ------------------------- wide-N qkv projection -----------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", ["rms", "layer"])
def test_qkv_proj_prologue_parity(rng, dtype, kind):
    """[norm-prologue + stored wq|wk|wv panel] vs norm -> three matmuls."""
    d = 96
    x = _rand(rng, (2, 19, d), dtype)
    ws = [_rand(rng, (d, 64), dtype), _rand(rng, (d, 32), dtype),
          _rand(rng, (d, 32), dtype)]
    bs = [_rand(rng, (64,)), jnp.zeros((32,)), _rand(rng, (32,))]
    w_fused = jnp.concatenate(ws, axis=-1)     # the stored param layout
    b_fused = jnp.concatenate(bs)
    g = _rand(rng, (d,))
    b = _rand(rng, (d,)) if kind == "layer" else None
    norm = ops.NormSpec(kind, g, b)
    q, k, v = ops.qkv_proj(x, w_fused, (64, 32, 32), bias=b_fused,
                           norm=norm, impl="interpret")
    xn = ref.layernorm_ref(x.reshape(-1, d), g, b, kind=kind)
    for got, w, bias in zip((q, k, v), ws, bs):
        want = ref.matmul_ref(xn, w, bias=bias).reshape(got.shape)
        _close(got, want, dtype)


def test_qkv_proj_int8_wide_n(rng):
    """int8 wide-N: weights AND per-channel scales concatenate along N."""
    d, m = 64, 33
    x = _rand(rng, (m, d))
    ws = [_rand(rng, (d, 32)), _rand(rng, (d, 16)), _rand(rng, (d, 16))]
    xq, xs = quantize_per_row(x)
    qs = [quantize_per_channel(w) for w in ws]
    w_cat = jnp.concatenate([q for q, _ in qs], axis=1)
    s_cat = jnp.concatenate([s for _, s in qs], axis=1)
    got = ops.matmul_int8(xq, w_cat, xs, s_cat, wide_n=True,
                          impl="interpret")
    want = jnp.concatenate(
        [ref.matmul_int8_ref(xq, q, xs.reshape(-1, 1), s)
         for q, s in qs], axis=-1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ------------------------- gated gate|up kernel ------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("with_bias", [False, True])
def test_gate_up_proj_parity(rng, dtype, with_bias):
    """One kernel for act(x@wg) * (x@wi) (+ fused pre-norm), streaming
    the halves of the stored wg|wi panel."""
    d, f = 64, 96
    x = _rand(rng, (2, 13, d), dtype)
    wg, wi = _rand(rng, (d, f), dtype), _rand(rng, (d, f), dtype)
    wgi = jnp.concatenate([wg, wi], axis=-1)   # the stored param layout
    bg = _rand(rng, (f,)) if with_bias else None
    bi = _rand(rng, (f,)) if with_bias else None
    bias = jnp.concatenate([bg, bi]) if with_bias else None
    g = _rand(rng, (d,))
    norm = ops.NormSpec("rms", g)
    got = ops.gate_up_proj(x, wgi, activation="silu", bias=bias,
                           norm=norm, impl="interpret")
    want = ref.pipeline_ref(x.reshape(-1, d), wi, bias=bi, w_gate=wg,
                            bias_gate=bg, activation="silu",
                            norm_kind="rms", gamma=g).reshape(got.shape)
    _close(got, want, dtype)


def test_gate_up_int8_kernel(rng):
    """Gated epilogue under W8A8: per-weight dequant scales."""
    d, f, m = 64, 48, 24
    x, wg, wi = _rand(rng, (m, d)), _rand(rng, (d, f)), _rand(rng, (d, f))
    xq, xs = quantize_per_row(x)
    wgq, wgs = quantize_per_channel(wg)
    wiq, wis = quantize_per_channel(wi)
    got = rowwise_matmul_p(xq, wiq, x_scale=xs.reshape(-1, 1), w_scale=wis,
                           w_gate=wgq, wg_scale=wgs, activation="silu",
                           interpret=True)
    want = (jax.nn.silu(ref.matmul_int8_ref(xq, wgq, xs.reshape(-1, 1), wgs))
            * ref.matmul_int8_ref(xq, wiq, xs.reshape(-1, 1), wis))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gate_up_ksplit(rng):
    """Gated accumulation across a forced k_splits > 1 adder tree."""
    m, k, f = 16, 300, 128
    x, wg, wi = _rand(rng, (m, k)), _rand(rng, (k, f)), _rand(rng, (k, f))
    plan = plan_matmul(m, k, f, dtype_bytes=4, k_max=128, n_weights=2)
    assert plan.k_splits > 1
    got = rowwise_matmul_p(x, wi, w_gate=wg, activation="silu", plan=plan,
                           interpret=True)
    want = jax.nn.silu(x @ wg) * (x @ wi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------- norm prologue -----------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", ["rms", "layer"])
def test_norm_prologue_padded_k(rng, dtype, kind):
    """K=100 lane-pads to 128: stats must mask the padded tail."""
    m, k, n = 17, 100, 64
    x, w = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
    g = _rand(rng, (k,))
    b = _rand(rng, (k,)) if kind == "layer" else None
    got = ops.matmul(x, w, norm=ops.NormSpec(kind, g, b), impl="interpret")
    want = ref.matmul_ref(ref.layernorm_ref(x, g, b, kind=kind), w)
    _close(got, want, dtype)


def test_norm_prologue_fallback_large_k(rng):
    """K beyond one VMEM panel: standalone norm + fused rest, 2 calls."""
    m, k, n = 4, 9000, 64
    x, w, g = _rand(rng, (m, k)), _rand(rng, (k, n)), _rand(rng, (k,))
    norm = ops.NormSpec("rms", g)
    got = ops.matmul(x, w, norm=norm, impl="interpret")
    want = ref.matmul_ref(ref.layernorm_ref(x, g, None, kind="rms"), w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-3)
    jaxpr = jax.make_jaxpr(
        lambda a, b, c: ops.matmul(a, b, norm=ops.NormSpec("rms", c),
                                   impl="interpret"))(x, w, g)
    # structured launch count via the auditor, not a string match
    assert analysis.count_primitive(jaxpr, "pallas_call") == 2, \
        str(jaxpr)


# ------------------------- residual epilogue ---------------------------


@pytest.mark.parametrize("dtype", DTYPES)
def test_residual_epilogue(rng, dtype):
    m, k, n = 24, 64, 48
    x, w = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
    b, res = _rand(rng, (n,)), _rand(rng, (m, n), dtype)
    got = ops.matmul(x, w, bias=b, activation="gelu", residual=res,
                     impl="interpret")
    want = ref.pipeline_ref(x, w, bias=b, activation="gelu", residual=res)
    _close(got, want, dtype)


def test_residual_epilogue_int8(rng):
    m, k, n = 33, 96, 64
    x, w = _rand(rng, (m, k)), _rand(rng, (k, n))
    res = _rand(rng, (m, n))
    xq, xs = quantize_per_row(x)
    wq, ws = quantize_per_channel(w)
    got = ops.matmul_int8(xq, wq, xs, ws, residual=res, impl="interpret")
    want = ref.matmul_int8_ref(xq, wq, xs.reshape(-1, 1), ws) + res
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------- flash-attention score bias ---------------------


@pytest.mark.parametrize("nb", [1, 4])
def test_flash_attention_bias(rng, nb):
    """Additive bias vs dense ref; nb=1 exercises the head-major grid."""
    b, h, t, hd = 8, 3, 49, 32
    q, k, v = (_rand(rng, (b, h, t, hd)) for _ in range(3))
    bias = _rand(rng, (nb, h, t, t))
    got = ops.attention(q, k, v, causal=False, bias=bias, impl="interpret")
    want = ref.attention_ref(q, k, v, causal=False, bias=bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bias_gqa(rng):
    b, hq, hkv, s, hd = 2, 8, 2, 64, 32
    q = _rand(rng, (b, hq, s, hd))
    k, v = _rand(rng, (b, hkv, s, hd)), _rand(rng, (b, hkv, s, hd))
    bias = _rand(rng, (1, hq, s, s))
    got = ops.attention(q, k, v, causal=True, bias=bias, impl="interpret")
    want = ref.attention_ref(q, k, v, causal=True, bias=bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ------------------- per-sublayer-pair launch budget -------------------


def _lm_cfg():
    return ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                       act="silu", norm="rms")


def test_sublayer_pair_pallas_call_budget():
    """Fused attn+MLP sublayer pair: <= 4 dense-pipeline launches
    ([norm+qkv], [wo+res], [norm+gate|up], [wo+res]) plus the
    attention-core kernel — down from ~9 per-op launches. The counting
    harness is shared with the BENCH_PR2.json artifact."""
    from benchmarks.block_bench import sublayer_pallas_calls
    fused = sublayer_pallas_calls(True)
    unfused = sublayer_pallas_calls(False)
    assert fused - 1 <= 4, fused          # minus the attention core
    assert unfused - 1 >= 9, unfused      # the seed's per-op pipeline
    assert fused <= unfused - 5


# ----------------------- fused vs unfused parity -----------------------


def test_lm_block_fused_parity(rng):
    cfg = _lm_cfg()
    blk = BlockDef(mixer="attn", ffn="mlp")
    params, _ = blocks.init_block(jax.random.PRNGKey(1), blk, cfg, None,
                                  jnp.float32)
    x = _rand(rng, (2, 16, 64))
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    with runtime.use_pipeline_fusion(True):
        xf, _ = blocks.apply_block(blk, params, x, cfg=cfg, mode="train",
                                   positions=pos)
    with runtime.use_pipeline_fusion(False):
        xu, _ = blocks.apply_block(blk, params, x, cfg=cfg, mode="train",
                                   positions=pos)
    np.testing.assert_allclose(np.asarray(xf), np.asarray(xu),
                               rtol=2e-5, atol=2e-5)


def test_decode_fused_parity(rng):
    cfg = _lm_cfg()
    blk = BlockDef(mixer="attn", ffn="mlp")
    params, _ = blocks.init_block(jax.random.PRNGKey(2), blk, cfg, None,
                                  jnp.float32)
    x = _rand(rng, (2, 1, 64))
    cache = {"kv": attention.init_cache(cfg, 2, 32, jnp.float32)}
    lengths = jnp.array([5, 9])
    outs = []
    for fused in (True, False):
        with runtime.use_pipeline_fusion(fused):
            xo, io = blocks.apply_block(blk, params, x, cfg=cfg,
                                        mode="decode", lengths=lengths,
                                        cache=cache)
        outs.append((xo, io.new_cache["kv"]))
    np.testing.assert_allclose(np.asarray(outs[0][0]),
                               np.asarray(outs[1][0]),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(outs[0][1].k),
                               np.asarray(outs[1][1].k),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_swin_forward_fused_parity():
    """Whole reduced-Swin forward: fused pipeline (incl. flash window
    attention with rel-pos bias) == the seed per-op path."""
    from repro.configs.swin_t import reduced as swin_reduced
    from repro.models import vision
    cfg = swin_reduced()
    key = jax.random.PRNGKey(0)
    p = vision.init_swin(key, cfg)
    img = jax.random.normal(key, (2, cfg.img_size, cfg.img_size, 3))
    with runtime.use_pipeline_fusion(True):
        lf = vision.swin_forward(p, img, cfg)
    with runtime.use_pipeline_fusion(False):
        lu = vision.swin_forward(p, img, cfg)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lu),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_vit_forward_fused_parity():
    from repro.configs.swin_t import ViTConfig
    from repro.models import vision
    cfg = ViTConfig(img_size=32, patch=8, embed_dim=64, depth=2,
                    num_heads=4, num_classes=10)
    key = jax.random.PRNGKey(0)
    p = vision.init_vit(key, cfg)
    img = jax.random.normal(key, (2, 32, 32, 3))
    with runtime.use_pipeline_fusion(True):
        lf = vision.vit_forward(p, img, cfg)
    with runtime.use_pipeline_fusion(False):
        lu = vision.vit_forward(p, img, cfg)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lu),
                               rtol=2e-4, atol=2e-4)


# ----------------------- modeled HBM-traffic win -----------------------


def test_swin_block_traffic_ratio():
    """Acceptance: one Swin-T block forward moves >= 1.8x less modeled
    HBM traffic fused than per-op (stage-1, non-shifted headline)."""
    kw = swin_t_stage_cases()["stage1"]
    fused = swin_block_traffic(**kw, fused=True)["total"]
    unfused = swin_block_traffic(**kw, fused=False)["total"]
    assert unfused / fused >= 1.8, (fused, unfused)


def test_swin_block_traffic_improves_everywhere():
    for name, kw in swin_t_stage_cases().items():
        for shifted in (False, True):
            fused = swin_block_traffic(**kw, shifted=shifted,
                                       fused=True)["total"]
            unfused = swin_block_traffic(**kw, shifted=shifted,
                                         fused=False)["total"]
            assert unfused / fused > 1.3, (name, shifted, fused, unfused)
