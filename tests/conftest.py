import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def manual_greedy(params, cfg, prompt, n_new, max_len):
    """Dense-cache greedy decode: the serving engines' parity oracle."""
    logits, cache = lm.prefill(params, prompt[None], cfg, alloc=max_len)
    toks = [int(jnp.argmax(logits[0]))]
    lengths = jnp.asarray([prompt.shape[0]], jnp.int32)
    for _ in range(n_new - 1):
        lg, cache = lm.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
            lengths, cfg)
        toks.append(int(jnp.argmax(lg[0])))
        lengths = lengths + 1
    return toks
