import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm

try:
    # CI property runs must be reproducible: a derandomized profile is
    # registered and active by default, so every run replays the same
    # example sequence (no flaky shrink chains, failures reproduce from
    # the printed blob). Set HYPOTHESIS_PROFILE=dev locally to explore
    # fresh random examples, or HYPOTHESIS_SEED=<n> to pin a specific
    # non-derandomized draw sequence.
    import random

    from hypothesis import settings

    _seed = os.environ.get("HYPOTHESIS_SEED")
    settings.register_profile("ci", derandomize=_seed is None,
                              deadline=None, print_blob=True)
    settings.register_profile("dev", deadline=None)
    if _seed is not None:
        random.seed(int(_seed))      # hypothesis's entropy fallback
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:                  # fast tier: no hypothesis installed
    pass


@pytest.fixture(autouse=True, scope="module")
def _bound_live_executables():
    """Drop compiled programs between test modules. A full-suite process
    otherwise accumulates every module's jitted engines plus the eager
    dense-oracle scans; past a few hundred live XLA:CPU executables a
    late compile segfaults inside backend_compile. Modules don't share
    engines, so per-module clearing only re-pays the handful of common
    oracle programs."""
    yield
    jax.clear_caches()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def manual_greedy(params, cfg, prompt, n_new, max_len):
    """Dense-cache greedy decode: the serving engines' parity oracle."""
    logits, cache = lm.prefill(params, prompt[None], cfg, alloc=max_len)
    toks = [int(jnp.argmax(logits[0]))]
    lengths = jnp.asarray([prompt.shape[0]], jnp.int32)
    for _ in range(n_new - 1):
        lg, cache = lm.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
            lengths, cfg)
        toks.append(int(jnp.argmax(lg[0])))
        lengths = lengths + 1
    return toks
