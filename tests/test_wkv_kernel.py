"""Pallas WKV6 kernel vs the naive recurrence oracle (interpret mode),
sweeping shapes/dtypes per the brief."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv import wkv_p
from repro.models.rwkv6 import CLAMP, wkv_ref


def _inputs(rng, b, s, h, p, dtype=jnp.float32):
    r, k, v = (jnp.asarray(rng.normal(size=(b, s, h, p)),
                           jnp.float32).astype(dtype) for _ in range(3))
    lw = jnp.clip(-jnp.exp(jnp.asarray(rng.normal(size=(b, s, h, p)),
                                       jnp.float32)), -CLAMP, -1e-6)
    u = jnp.asarray(rng.normal(size=(h, p)), jnp.float32)
    return r, k, v, lw, u


@pytest.mark.parametrize("b,s,h,p", [(2, 45, 3, 16), (1, 16, 1, 8),
                                     (2, 64, 2, 32), (1, 7, 2, 16)])
def test_wkv_kernel_matches_ref(rng, b, s, h, p):
    r, k, v, lw, u = _inputs(rng, b, s, h, p)
    y1, s1 = wkv_p(r, k, v, lw, u, interpret=True)
    y2, s2 = wkv_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=3e-4, atol=3e-4)


def test_wkv_kernel_bf16(rng):
    r, k, v, lw, u = _inputs(rng, 1, 32, 2, 16, jnp.bfloat16)
    y1, _ = wkv_p(r, k, v, lw, u, interpret=True)
    y2, _ = wkv_ref(r.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32), lw, u)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2), rtol=5e-2, atol=5e-2)


def test_wkv_kernel_chunk_sizes(rng):
    r, k, v, lw, u = _inputs(rng, 1, 40, 2, 16)
    y_ref, _ = wkv_ref(r, k, v, lw, u)
    for chunk in (8, 16):
        y, _ = wkv_p(r, k, v, lw, u, chunk=chunk, interpret=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=3e-4, atol=3e-4)
