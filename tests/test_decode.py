"""Prefill + step-by-step decode must match the teacher-forced forward
pass — the strongest cache-correctness property, covering GQA/MQA KV
caches, gemma's sliding-window ring buffers, Mamba2 conv/SSM states,
RWKV token-shift/WKV states, M-RoPE and whisper cross-attention."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REDUCED
from repro.models import lm

pytestmark = pytest.mark.slow  # full prefill+decode per arch, minutes on CPU

CASES = ["deepseek-7b", "gemma3-27b", "zamba2-1.2b", "rwkv6-3b",
         "qwen2-vl-2b", "whisper-base", "granite-20b", "internlm2-20b"]


@pytest.mark.parametrize("arch", CASES)
def test_prefill_decode_matches_forward(arch):
    cfg = REDUCED[arch]()
    key = jax.random.PRNGKey(1)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    b, s, t0 = 2, 24, 16
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    extra = {}
    if cfg.encdec:
        extra["frames"] = jax.random.normal(
            key, (b, cfg.cross_len, cfg.d_model), jnp.float32)
    full, _ = lm.forward(params, tokens, cfg, extra=extra or None,
                         remat=False)
    lg, cache = lm.prefill(params, tokens[:, :t0], cfg,
                           extra=extra or None, alloc=s)
    errs = [float(jnp.max(jnp.abs(lg - full[:, t0 - 1])))]
    lengths = jnp.full((b,), t0, jnp.int32)
    for t in range(t0, s):
        lg, cache = lm.decode_step(params, cache, tokens[:, t:t + 1],
                                   lengths, cfg)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
        lengths = lengths + 1
    assert max(errs) < 2e-4, f"{arch}: {errs}"


def test_ring_buffer_wraps(rng):
    """gemma-style windowed layer: decode far past the window size."""
    cfg = REDUCED["gemma3-27b"]()
    key = jax.random.PRNGKey(3)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    b, s = 1, 40          # window=16 in the smoke config; 40 >> 16
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    full, _ = lm.forward(params, tokens, cfg, remat=False)
    lg, cache = lm.prefill(params, tokens[:, :8], cfg, alloc=s)
    lengths = jnp.full((b,), 8, jnp.int32)
    errs = []
    for t in range(8, s):
        lg, cache = lm.decode_step(params, cache, tokens[:, t:t + 1],
                                   lengths, cfg)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
        lengths = lengths + 1
    assert max(errs) < 2e-4


def test_standalone_cache_decode():
    """Decode against a zero cache (the decode dry-run cell pattern)."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(4)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    cache = lm.init_cache(cfg, 2, 32, jnp.float32)
    lengths = jnp.zeros((2,), jnp.int32)
    tok = jax.random.randint(key, (2, 1), 0, cfg.vocab)
    logits, cache2 = lm.decode_step(params, cache, tok, lengths, cfg)
    assert logits.shape == (2, lm.padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
