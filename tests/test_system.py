"""End-to-end behaviour: tiny model trains (loss drops on the synthetic
Markov language), survives a simulated preemption (checkpoint/restore
resumes exactly), and the NaN guard skips poisoned steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.core.types import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import lm
from repro.optim import adamw
from repro.train import step as tsl

pytestmark = pytest.mark.slow  # end-to-end training loops


def _tiny_cfg():
    return ModelConfig(name="tiny", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab=64, act="silu", norm="rms")


def _pipeline(cfg, b=8, s=32):
    return SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=s,
                                  global_batch=b, seed=7))


def test_loss_decreases():
    cfg = _tiny_cfg()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    tcfg = tsl.TrainConfig(
        opt=adamw.AdamWConfig(lr=3e-3), warmup_steps=5, total_steps=60,
        remat=False)
    state = tsl.init_state(params, tcfg)
    step = jax.jit(tsl.make_train_step(cfg, tcfg))
    ds = _pipeline(cfg)
    losses = []
    for i in range(60):
        batch = jax.tree.map(jnp.asarray, ds.batch(i))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    # synthetic Markov stream is learnable: expect a solid drop
    assert last < first - 0.5, (first, last)


def test_preemption_resume_exact(tmp_path):
    cfg = _tiny_cfg()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    tcfg = tsl.TrainConfig(warmup_steps=2, total_steps=20, remat=False)
    step = jax.jit(tsl.make_train_step(cfg, tcfg))
    ds = _pipeline(cfg)

    # run A: 10 uninterrupted steps
    state = tsl.init_state(params, tcfg)
    for i in range(10):
        state, _ = step(state, jax.tree.map(jnp.asarray, ds.batch(i)))
    ref = state

    # run B: preempted at step 6, resumed from checkpoint + data step
    state = tsl.init_state(params, tcfg)
    for i in range(6):
        state, _ = step(state, jax.tree.map(jnp.asarray, ds.batch(i)))
    ckpt.save(str(tmp_path), 6, state, extra={"data_step": 6})
    restored, extra = ckpt.restore(str(tmp_path), 6, state)
    for i in range(extra["data_step"], 10):
        restored, _ = step(restored,
                           jax.tree.map(jnp.asarray, ds.batch(i)))
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(ref.params), jax.tree.leaves(restored.params))]
    assert max(diffs) < 1e-6, max(diffs)


def test_nan_guard_skips_bad_step():
    cfg = _tiny_cfg()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    tcfg = tsl.TrainConfig(remat=False, skip_nonfinite=True)
    state = tsl.init_state(params, tcfg)
    step = jax.jit(tsl.make_train_step(cfg, tcfg))
    ds = _pipeline(cfg)
    good = jax.tree.map(jnp.asarray, ds.batch(0))
    state1, m1 = step(state, good)
    assert float(m1["skipped"]) == 0.0
    # poison the gradient path: inf embeddings make the loss non-finite
    bad_state = state1._replace(params={
        **state1.params, "embed": state1.params["embed"] * jnp.inf})
    state2, m2 = step(bad_state, good)
    assert float(m2["skipped"]) == 1.0
    for a, b in zip(jax.tree.leaves(bad_state.params),
                    jax.tree.leaves(state2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
