"""MoE routing/dispatch correctness (local path; EP path in test_dist)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REDUCED
from repro.core.types import ModelConfig, MoEConfig
from repro.models import moe


def _cfg(e=4, k=2, cf=8.0, n_shared=0):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=32, vocab=64, act="silu",
        moe=MoEConfig(n_experts=e, top_k=k, d_ff=32,
                      capacity_factor=cf, n_shared=n_shared))


def _dense_reference(params, x, cfg):
    """Route every token through its top-k experts with NO capacity —
    ground truth when capacity is generous."""
    mo = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    gate_vals, gate_idx, _ = moe._route(xf, params["router"], cfg,
                                        moe.padded_experts(cfg))
    outs = moe._expert_mlp(
        jnp.broadcast_to(xf[None], (params["wi"].shape[0],) + xf.shape),
        params["wi"], params["wg"], params["wo"])     # (E, T, d)
    y = jnp.zeros_like(xf, jnp.float32)
    for slot in range(mo.top_k):
        idx = gate_idx[:, slot]
        y = y + gate_vals[:, slot, None] * outs[
            idx, jnp.arange(xf.shape[0])]
    if mo.n_shared:
        y = y + moe._shared_expert(params, xf)
    return y.reshape(b, s, d)


def test_moe_matches_dense_reference(rng):
    cfg = _cfg(cf=16.0)   # capacity never binds
    key = jax.random.PRNGKey(0)
    params, _ = moe.init(key, cfg, stack=None, dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    got, aux = moe.apply(params, x, cfg=cfg)
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_capacity_drops_tokens():
    """With capacity 1 token per expert, dropped tokens contribute 0."""
    cfg = _cfg(e=2, k=1, cf=1e-6)
    key = jax.random.PRNGKey(0)
    params, _ = moe.init(key, cfg, stack=None, dtype=jnp.float32)
    x = jax.random.normal(key, (1, 6, 16))
    got, _ = moe.apply(params, x, cfg=cfg)
    # cap = max(~0, k) = 1 per expert: at most 2 of 6 tokens get output
    nonzero = jnp.sum(jnp.any(jnp.abs(got) > 1e-9, axis=-1))
    assert int(nonzero) <= 2


def test_shared_experts_active():
    cfg = _cfg(n_shared=1, cf=16.0)
    key = jax.random.PRNGKey(0)
    params, _ = moe.init(key, cfg, stack=None, dtype=jnp.float32)
    x = jax.random.normal(key, (1, 4, 16))
    got, _ = moe.apply(params, x, cfg=cfg)
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_expert_padding_never_routed():
    """qwen2-moe pads 60 -> 64: pad experts must receive zero traffic."""
    cfg = REDUCED["qwen2-moe-a2.7b"]()
    assert moe.padded_experts(cfg) == cfg.moe.n_experts  # smoke: e<16
    big = _cfg(e=60, k=4)
    assert moe.padded_experts(big) == 64
    key = jax.random.PRNGKey(0)
    params, _ = moe.init(key, big, stack=None, dtype=jnp.float32)
    x = jax.random.normal(key, (1, 32, 16))
    xf = x.reshape(-1, 16)
    _, gate_idx, probs = moe._route(xf, params["router"], big, 64)
    assert int(jnp.max(gate_idx)) < 60
    assert float(jnp.max(probs[:, 60:])) == 0.0


def test_aux_loss_balanced_routing_lower():
    """Perfectly balanced routing yields lower aux loss than collapsed
    (router probs consistent with the assignments in each case)."""
    cfg = _cfg(e=4, k=1)
    t, e = 64, 4
    balanced = jnp.tile(jnp.arange(e), t // e)[:, None]
    probs_bal = jnp.full((t, e), 0.25)
    collapsed = jnp.zeros((t, 1), jnp.int32)
    probs_col = jnp.full((t, e), 0.01).at[:, 0].set(0.97)
    lb = moe._aux_loss(balanced, probs_bal, cfg)
    lc = moe._aux_loss(collapsed, probs_col, cfg)
    assert float(lb) < float(lc)
