"""Faithful-reproduction checks against the paper's own claims.

Paper: Wang & Chang, "Row-wise Accelerator for Vision Transformer", 2022.
  - Table III: 403.2 GOPS peak @ 600 MHz, 336 MACs
  - Table IV:  ~22.4 ms / image => ~44.5 img/s on Swin-T
  - Sec. V:    overall utilization ~99%
  - Fig. 2:    FC >= 97% of FLOPs, >= 83% of params
  - Sec. IV-C: 448 cycles per conv output channel on 224x224
"""
import math

from repro.configs.swin_t import CONFIG as SWIN_T
from repro.core.asic_model import (ASIC, ASICGeometry, op_cycles, run_asic,
                                   swin_ops, swin_params)
from repro.core.rowwise import OpRecord


def test_peak_throughput_exact():
    assert ASIC.macs == 336                      # 12 blocks x 7 rows x 4
    assert abs(ASIC.peak_gops - 403.2) < 1e-9    # Table III


def test_conv_cycles_match_paper():
    # Sec. IV-C: 224x224 image => 56x56 outputs, 7/cycle => 448 cycles
    # per output channel.
    op = OpRecord("patch", "conv", m=56 * 56, k=48, n=1)
    assert op_cycles(op) == 448


def test_swin_t_latency_and_throughput():
    rep = run_asic(swin_ops(SWIN_T))
    # Swin-T ~4.5 GMACs (the paper's 22.4 ms at 403.2 GOPS implies
    # 4.5e9 MACs); our walk must land within 5% of both claims.
    assert abs(rep.total_macs - 4.5e9) / 4.5e9 < 0.05
    assert abs(rep.time_s * 1e3 - 22.4) / 22.4 < 0.05       # Table IV
    assert abs(rep.images_per_s - 44.5) / 44.5 < 0.05       # Table IV
    assert rep.utilization >= 0.97                          # Sec. V "~99%"


def test_fig2_flops_distribution():
    rep = run_asic(swin_ops(SWIN_T))
    shares = rep.flops_shares()
    assert shares["fc"] >= 0.95          # paper: >97% (we classify merge
    assert shares["conv"] <= 0.01        # + head as fc; within 2%)
    assert shares["attn"] <= 0.04        # paper: <=3% for MHA


def test_fig2_param_distribution():
    p = swin_params(SWIN_T)
    total = sum(p.values())
    assert p["fc"] / total >= 0.83       # paper: >83%


def test_attention_uses_8_blocks():
    # Sec. IV-E: attention runs on 8 of 12 blocks => 2/3 peak util
    op = OpRecord("qk", "attn", m=49, k=32, n=49)
    cyc = op_cycles(op)
    util = op.macs / (ASIC.macs * cyc)
    assert abs(util - 8 / 12) < 1e-6


def test_gops_scale_with_geometry():
    big = ASICGeometry(blocks=24)
    assert abs(big.peak_gops - 2 * ASIC.peak_gops) < 1e-9
