"""Data pipeline determinism/seekability + checkpointer guarantees."""
import os
import zipfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticLM


def test_batch_is_pure_function_of_step():
    cfg = DataConfig(vocab=256, seq_len=32, global_batch=4)
    ds1, ds2 = SyntheticLM(cfg), SyntheticLM(cfg)
    for step in (0, 7, 12345):
        b1, b2 = ds1.batch(step), ds2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds1.batch(1)["tokens"],
                              ds1.batch(2)["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=256, seq_len=32, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    # label[t] is the next token: verify the stream is learnable
    # (deterministic fraction of transitions repeats across batches)
    assert b["tokens"].shape == b["labels"].shape == (2, 32)


def test_host_sharding_disjoint():
    full = SyntheticLM(DataConfig(vocab=64, seq_len=8, global_batch=8))
    h0 = SyntheticLM(DataConfig(vocab=64, seq_len=8, global_batch=8,
                                n_hosts=2, host_id=0))
    h1 = SyntheticLM(DataConfig(vocab=64, seq_len=8, global_batch=8,
                                n_hosts=2, host_id=1))
    assert h0.local_batch == h1.local_batch == 4
    b0, b1 = h0.batch(3), h1.batch(3)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_resume_exactly(tmp_path):
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
    ds = SyntheticLM(cfg)
    it = ds.iter_from(5)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], ds.batch(5)["tokens"])


def test_prefetch_iterator():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
    ds = SyntheticLM(cfg)
    it = PrefetchIterator(ds.iter_from(0), depth=2)
    got = [next(it) for _ in range(3)]
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"], ds.batch(i)["tokens"])
    it.close()


# ---------------------------- checkpointer ----------------------------


def _tree(key):
    return {"a": jax.random.normal(key, (8, 4)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32)},
            "scalar": jnp.float32(3.5)}


def test_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 3, tree, extra={"data_step": 3})
    restored, extra = ckpt.restore(str(tmp_path), 3, tree)
    assert extra["data_step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ac.save_async(s, tree)
    ac.wait()
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]


def test_corruption_detected(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    d = ckpt.save(str(tmp_path), 1, tree)
    shard = os.path.join(d, "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00\x00\x00")
    # checksum mismatch (IOError) is the designed failure; a torn npz
    # can also fail inside numpy's zip reader before the checksum runs
    with pytest.raises((IOError, ValueError, zipfile.BadZipFile)):
        ckpt.restore(str(tmp_path), 1, tree)


def test_atomicity_tmp_never_latest(tmp_path):
    tree = _tree(jax.random.PRNGKey(3))
    ckpt.save(str(tmp_path), 1, tree)
    # a stale .tmp dir (simulated crash) must not be picked up
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 1
