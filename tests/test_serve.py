"""Serving engine: continuous batching equals manual greedy decoding."""
import jax
import jax.numpy as jnp
import pytest

from conftest import manual_greedy

from repro.configs import REDUCED
from repro.models import lm
from repro.serve import sampling
from repro.serve.engine import Engine, Request

pytestmark = pytest.mark.slow  # engine decode loops, ~20s+ on CPU


def test_engine_matches_manual_decode():
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    prompts = [jax.random.randint(jax.random.fold_in(key, i),
                                  (6 + i,), 0, cfg.vocab)
               for i in range(3)]
    n_new = 5
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=n_new))
    done = eng.run()
    assert len(done) == 3
    by_rid = {c.rid: c for c in done}
    for i, p in enumerate(prompts):
        want = manual_greedy(params, cfg, p, n_new, 32)
        assert by_rid[i].tokens == want, (i, by_rid[i].tokens, want)


def test_continuous_batching_refills_slots():
    cfg = REDUCED["rwkv6-3b"]()
    key = jax.random.PRNGKey(1)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    eng = Engine(params, cfg, n_slots=2, max_len=24, eos_id=-1)
    for i in range(5):   # more requests than slots
        eng.submit(Request(rid=i, prompt=jax.random.randint(
            jax.random.fold_in(key, i), (4,), 0, cfg.vocab), max_new=3))
    done = eng.run()
    assert sorted(c.rid for c in done) == [0, 1, 2, 3, 4]
    assert all(len(c.tokens) == 3 for c in done)


def test_sampling_modes():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sampling.greedy(logits)[0]) == 1
    s = sampling.sample(logits, key, temperature=0.5, top_k=2)
    assert int(s[0]) in (1, 2)
    s = sampling.sample(logits, key, temperature=1.0, top_p=0.5)
    assert int(s[0]) == 1
