"""Seeded-violation fixtures for the static invariant auditor.

Every pass gets the same treatment: a fixture that MUST fire with its
documented RWA code, and clean code (a minimal snippet plus the shipped
serving modules) that MUST stay quiet. The pair is what makes a green
`python -m repro.analysis.audit` meaningful — a pass that cannot fail
proves nothing.
"""
import dataclasses
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (compile_bound, donation, rules, sync, vmem)
from repro.analysis.audit import (ENGINE_SYNC_ALLOW, RULE_MODULES,
                                  SERVE_DIR_MODULES)
from repro.analysis.report import CODES, Diagnostic, PassResult
from repro.core.rowwise import plan_matmul
from repro.kernels import ops

jax.config.update("jax_enable_x64", False)

SERVE_DIR = os.path.join(os.path.dirname(os.path.abspath(sync.__file__)),
                         os.pardir, "serve")


def _codes(result: PassResult):
    return sorted({d.code for d in result.diagnostics})


def _sync(src, **kw):
    return sync.audit_source(textwrap.dedent(src), path="fixture.py",
                             **kw)


def _rules(src, **kw):
    return rules.audit_source(textwrap.dedent(src), path="fixture.py",
                              **kw)


# ---------------------------------------------------------------- report

def test_diagnostic_rejects_unregistered_code():
    with pytest.raises(AssertionError):
        Diagnostic(code="RWA999", message="no such rule", path="x",
                   line=1)


def test_pass_result_ok_tracks_error_severity():
    res = PassResult(name="sync")
    assert res.ok
    res.diagnostics.append(Diagnostic(code="RWA101", message="m",
                                      path="x", line=1))
    assert not res.ok and len(res.errors()) == 1
    assert "RWA101" in str(res.errors()[0])
    assert set(CODES) >= {d.code for d in res.diagnostics}


# ------------------------------------------------------------- sync pass

def test_sync_item_on_device_value_fires():
    res = _sync("""
        import jax.numpy as jnp

        def bad(x):
            y = jnp.sum(x)
            return y.item()
    """)
    assert _codes(res) == ["RWA101"]


def test_sync_float_cast_fires():
    res = _sync("""
        import jax.numpy as jnp

        def bad(x):
            return float(jnp.mean(x))
    """)
    assert "RWA102" in _codes(res)


def test_sync_np_asarray_on_device_value_fires():
    res = _sync("""
        import jax.numpy as jnp
        import numpy as np

        def bad(a, b):
            y = jnp.dot(a, b)
            return np.asarray(y)
    """)
    assert "RWA103" in _codes(res)


def test_sync_taint_flows_through_unknown_calls():
    # helper(y) is opaque: its result must stay tainted, so the cast
    # two hops away from the producer still fires
    res = _sync("""
        import jax.numpy as jnp

        def bad(x, helper):
            y = jnp.sum(x)
            z = helper(y)
            return int(z)
    """)
    assert "RWA102" in _codes(res)


def test_sync_metadata_reads_are_not_syncs():
    res = _sync("""
        import jax.numpy as jnp

        def ok(x):
            y = jnp.sum(x)
            n = y.shape[0]
            return int(n) + int(y.ndim)
    """)
    assert res.ok and res.checked > 0


def test_sync_device_get_needs_allowlist():
    src = """
        import jax

        def fetch(x):
            return jax.device_get(x)
    """
    assert _codes(_sync(src)) == ["RWA104"]
    allowed = sync.SyncPolicy(device_get_allow={"fetch": 1})
    assert _sync(src, policy=allowed).ok


def test_sync_block_until_ready_fires():
    res = _sync("""
        import jax.numpy as jnp

        def bad(a, b):
            y = jnp.dot(a, b)
            return y.block_until_ready()
    """)
    assert "RWA105" in _codes(res)


def test_sync_entry_jaxpr_callback_fires():
    def with_cb(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    dirty = jax.make_jaxpr(with_cb)(jnp.ones(4))
    clean = jax.make_jaxpr(jnp.sin)(jnp.ones(4))
    res = sync.audit_entry_jaxprs([("dirty", dirty)])
    assert _codes(res) == ["RWA106"]
    assert sync.audit_entry_jaxprs([("clean", clean)]).ok


def test_shipped_serve_modules_sync_clean():
    """The regression half of the PR-9 fix: `submit()` owns the one
    prompt normalisation, so no serve module hides a per-step sync."""
    policy = sync.SyncPolicy(device_get_allow=dict(ENGINE_SYNC_ALLOW))
    for mod in SERVE_DIR_MODULES:
        res = sync.audit_file(os.path.join(SERVE_DIR, mod),
                              policy=policy)
        assert res.ok, f"{mod}: {[str(d) for d in res.errors()]}"
        assert res.checked > 0


# --------------------------------------------------------- donation pass

def test_donation_dropped_alias_fires():
    # b's only output is a scalar reduction: XLA cannot alias the
    # donated (8,) buffer anywhere, silently copies it, and the only
    # runtime trace is a UserWarning — exactly what the pass catches
    bad = jax.jit(lambda a, b: (a + 1.0, b.sum()), donate_argnums=(1,))
    args = (jnp.ones((4,), jnp.float32), jnp.ones((8,), jnp.float32))
    res = donation.audit_donation(bad, args, (1,), name="bad")
    assert "RWA201" in _codes(res) and "RWA202" in _codes(res)


def test_donation_aligned_buffer_clean():
    good = jax.jit(lambda a, b: (a + 1.0, b * 2.0), donate_argnums=(1,))
    args = (jnp.ones((4,), jnp.float32), jnp.ones((8,), jnp.float32))
    res = donation.audit_donation(good, args, (1,), name="good")
    assert res.ok and res.checked == 1


# ------------------------------------------------------------ rules pass

def test_rules_unpaired_begin_fires():
    res = _rules("""
        def leak(pool, slot):
            pool.begin()
            pool.admit(slot, 1)
    """)
    assert "RWA501" in _codes(res)


def test_rules_balanced_tx_with_rollback_clean():
    res = _rules("""
        def ok(pool, slot):
            pool.begin()
            try:
                pool.admit(slot, 1)
                pool.commit()
            except RuntimeError:
                pool.rollback()
                raise
    """)
    assert res.ok and res.checked > 0


def test_rules_eviction_inside_tx_fires():
    res = _rules("""
        def bad(pool, slot):
            pool.begin()
            pool._make_room(3)
            pool.admit(slot, 1)
            pool.commit()
    """)
    assert "RWA502" in _codes(res)


def test_rules_mutation_outside_tx_fires():
    res = _rules("""
        def bad(pool, slot):
            pool.admit(slot, 1)
    """)
    assert "RWA503" in _codes(res)


def test_rules_weight_concat_fires_and_is_optional():
    src = """
        import jax.numpy as jnp

        def fuse(parts):
            return jnp.concatenate(parts, axis=-1)
    """
    assert "RWA504" in _codes(_rules(src))
    assert _rules(src, concat_rule=False).ok


def test_shipped_serve_modules_rules_clean():
    for mod in RULE_MODULES:
        res = rules.audit_file(os.path.join(SERVE_DIR, mod),
                               concat_rule=(mod != "engine.py"))
        assert res.ok, f"{mod}: {[str(d) for d in res.errors()]}"


# ---------------------------------------------------- compile-bound pass

def test_enumeration_matches_documented_bound():
    # max_len=64, min_bucket=16 -> buckets (16, 32, 64); chunks are the
    # buckets <= prefill_chunk -> (16,); one full-width decode program
    inv = compile_bound.enumerate_programs(max_len=64, page_size=16,
                                           prefill_chunk=16)
    assert inv.prefill_lens == (16, 32, 64)
    assert inv.chunk_shapes == (16,)
    assert inv.step_widths == (4,)
    assert inv.bound == 3 + 1 + 1
    res = compile_bound.audit_bound(inv, n_buckets=3, n_chunk_shapes=1,
                                    max_pages=4)
    assert res.ok

    seeded = compile_bound.audit_bound(inv, n_buckets=2,
                                       n_chunk_shapes=1, max_pages=4)
    assert _codes(seeded) == ["RWA301"]


def test_enumeration_table_width_ladder():
    inv = compile_bound.enumerate_programs(max_len=64, page_size=16,
                                           table_width_bucketing=True)
    assert inv.step_widths == (1, 2, 4)
    res = compile_bound.audit_bound(inv, n_buckets=3, n_chunk_shapes=0,
                                    max_pages=4,
                                    table_width_bucketing=True)
    assert res.ok

    forged = dataclasses.replace(inv, step_widths=(1, 2, 4, 8))
    res = compile_bound.audit_bound(forged, n_buckets=3,
                                    n_chunk_shapes=0, max_pages=4,
                                    table_width_bucketing=True)
    assert _codes(res) == ["RWA301"]


def test_weak_type_operand_fires():
    weak = jax.make_jaxpr(lambda x, t: x * t)(jnp.ones(4), 2.0)
    strong = jax.make_jaxpr(lambda x, t: x * t)(jnp.ones(4),
                                                jnp.float32(2.0))
    assert _codes(compile_bound.weak_type_audit([("f", weak)])) \
        == ["RWA302"]
    assert compile_bound.weak_type_audit([("f", strong)]).ok


class _StubEngine:
    """compile_counts() and the host proxies disagree with each other
    AND with the static prediction — both RWA303 arms must fire."""
    _prefill_lens = {16}
    _chunk_shapes = ()
    _step_widths = {4}

    def compile_counts(self):
        return {"prefill": 2, "chunk": 0, "step": 1}


def test_runtime_count_drift_fires():
    expected = compile_bound.predict_compile_counts([3, 5], max_len=64)
    assert expected == {"prefill": 1, "chunk": 0, "step": 1}
    res = compile_bound.check_engine_counts(_StubEngine(), expected,
                                            name="stub")
    msgs = [d.message for d in res.diagnostics]
    assert _codes(res) == ["RWA303"]
    assert any("static enumeration" in m for m in msgs)
    assert any("host proxy" in m for m in msgs)


def test_prediction_models_chunk_padding():
    # 50 with chunk 16 -> 16,16,16 then the 2-token tail pads to the
    # 16 bucket: one distinct chunk shape, no one-shot prefill program
    got = compile_bound.predict_compile_counts(
        [50], max_len=64, prefill_chunk=16)
    assert got == {"prefill": 0, "chunk": 1, "step": 1}


# ------------------------------------------------------------- vmem pass

def test_vmem_overbudget_kernel_fires():
    from jax.experimental import pallas as pl

    def copy_kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    # whole-array 4096x4096 fp32 block: 64 MB in + 64 MB out, modeled
    # residency 192 MB vs the 14 MB post-headroom budget
    big = jax.ShapeDtypeStruct((4096, 4096), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda a: pl.pallas_call(
        copy_kernel, out_shape=big, interpret=True)(a))(big)
    res = vmem.audit_vmem(jaxpr, "fixture")
    assert _codes(res) == ["RWA401"] and res.checked == 1
    fp, = vmem.kernel_footprints(jaxpr)
    assert fp.resident_bytes == 3 * 4096 * 4096 * 4


def test_vmem_plan_crosscheck():
    m, k, n = 256, 16384, 512
    plan = plan_matmul(m, k, n, dtype_bytes=4)
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    w = jax.ShapeDtypeStruct((k, n), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda a, b: ops.matmul(a, b, impl="interpret"))(x, w)
    assert vmem.crosscheck_plan(jaxpr, plan, "matmul").ok

    forged = dataclasses.replace(plan, vmem_bytes=1)
    res = vmem.crosscheck_plan(jaxpr, forged, "matmul")
    assert "RWA402" in _codes(res)


# ------------------------------------------------- engine regression

def test_submit_normalises_prompt_to_host():
    """PR-9 regression: the auditor's RWA103 caught `_effective_prompt`
    re-fetching a device-resident prompt on every admission attempt;
    submit() now pays the transfer exactly once."""
    from repro.analysis.audit import build_engine
    from repro.serve.engine import Request

    eng, _ = build_engine("deepseek-7b", 1)
    eng.submit(Request(rid=0, prompt=jnp.arange(5, dtype=jnp.int32),
                       max_new=1))
    queued = eng.queue[-1].req.prompt
    assert isinstance(queued, np.ndarray)
    assert not isinstance(queued, jax.Array)
    assert queued.dtype == np.int32
    np.testing.assert_array_equal(queued, np.arange(5))
