"""Fault-tolerant serving (PR 7): request lifecycle, preemption with
transactional page rollback, recovery boundary, and the deterministic
fault-injection harness.

Fast section — FaultPlan semantics and PagePool transaction units (no
model). Slow section — engine-level lifecycle/fault tests on the
reduced deepseek config, including the ISSUE acceptance criteria:
under a seeded FaultPlan every rid reaches exactly one terminal
completion, pool accounting balances, the surviving engine then serves
a clean trace bit-identically to a fresh engine, and a
preempted-and-recomputed greedy stream equals its unpreempted one.
Chaos section — a hypothesis suite (marker ``chaos``) driving random
fault schedules against the lifecycle invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import manual_greedy

from repro.configs import REDUCED
from repro.core.types import PagingConfig
from repro.models import lm
from repro.serve.engine import TERMINAL_STATUSES, Engine, Request
from repro.serve.faults import (AllocFault, Fault, FaultPlan, StepFault,
                                parse_plan)
from repro.serve.paging import PagePool

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                  # fast tier: no hypothesis installed
    HAVE_HYPOTHESIS = False


# ----------------------------------------------------------------------
# FaultPlan units (fast)
# ----------------------------------------------------------------------


def test_fault_plan_parse_and_queries():
    plan = parse_plan("alloc@3,nan@5.1,exc@7,slow@2:0.01,nan@5")
    assert plan.alloc_fails(3) and not plan.alloc_fails(4)
    # slot-specific and all-slot poisoning at the same step both survive
    assert plan.poison_slots(5) == [None, 1]
    assert plan.poison_slots(6) is None
    assert plan.step_raises(7) and not plan.step_raises(3)
    assert plan.slow_s(2) == pytest.approx(0.01)
    assert plan.slow_s(3) == 0.0
    assert plan.max_step() == 7 and len(plan) == 5
    # the DSL round-trips through describe()
    assert parse_plan(plan.describe()) == plan
    assert parse_plan("") == FaultPlan() == parse_plan("  ")
    with pytest.raises(ValueError, match="bad --fault-plan"):
        parse_plan("nan@x")
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("frob", 1)
    with pytest.raises(ValueError, match="step must be >= 0"):
        Fault("nan", -1)


def test_fault_plan_random_is_seed_deterministic():
    kw = dict(n_steps=50, n_slots=4, p_alloc=0.3, p_nan=0.2, p_exc=0.1,
              p_slow=0.1)
    a, b = FaultPlan.random(7, **kw), FaultPlan.random(7, **kw)
    assert a == b and len(a) > 0
    assert FaultPlan.random(8, **kw) != a
    assert all(f.kind in ("alloc", "nan", "exc", "slow") for f in a.faults)


# ----------------------------------------------------------------------
# PagePool transaction units (fast)
# ----------------------------------------------------------------------


def test_pool_transaction_rollback_restores_state():
    pool = PagePool(8, 4, 2, 4)
    pool.admit(0, 10)
    pool.ensure(0, 10)
    free0, tables0 = list(pool.free), pool.tables.copy()
    v0 = pool.version
    pool.begin()
    pool.admit(1, 16)
    pool.ensure(1, 16)
    assert pool.live_pages() == 3 + 4
    pool.rollback()
    assert pool.free == free0
    assert (pool.tables == tables0).all()
    assert pool.n_alloc[1] == 0 and pool.reserved[1] == 0
    # rollback restores the tables but must still look "new" to the
    # engine's shipped-table key, or stale device tables would survive
    assert pool.version > v0
    pool.check_conservation()


def test_pool_transactions_nest():
    pool = PagePool(8, 4, 2, 4)
    pool.begin()
    pool.admit(0, 8)
    pool.ensure(0, 8)
    pool.begin()
    pool.admit(1, 8)
    pool.ensure(1, 8)
    pool.rollback()                  # inner: slot 1 gone
    assert pool.n_alloc[1] == 0 and pool.n_alloc[0] == 2
    pool.commit()                    # outer: slot 0 stays
    assert not pool.in_transaction()
    assert pool.n_alloc[0] == 2
    pool.check_conservation()


def test_pool_rollback_tail_returns_pages_keeps_reservation():
    pool = PagePool(8, 4, 1, 8)
    pool.admit(0, 30)                # 8 pages reserved
    pool.ensure(0, 30)               # 8 allocated
    assert pool.live_pages() == 8 and not pool.free
    freed = pool.rollback_tail(0, 9)      # keep ceil(9/4) = 3 pages
    assert freed == 5 and pool.n_alloc[0] == 3 and len(pool.free) == 5
    # freed tail entries point back at the slot's scratch page
    assert (pool.tables[0, 3:] == pool.scratch[0]).all()
    # the reservation is untouched: the worst case of the sequence is
    # unchanged by dropping its tail (speculative-decode contract)
    assert pool.reserved[0] == 8
    pool.ensure(0, 30)               # and the tail can regrow
    assert pool.n_alloc[0] == 8
    pool.check_conservation()
    assert pool.rollback_tail(0, 32) == 0     # covering keep is a no-op


def test_pool_alloc_hook_faults_inside_ensure():
    pool = PagePool(8, 4, 2, 4)
    calls = []

    def hook():
        calls.append(len(calls))
        if len(calls) == 1:
            raise AllocFault("injected")
    pool.alloc_hook = hook
    pool.begin()
    pool.admit(0, 12)
    with pytest.raises(AllocFault):
        pool.ensure(0, 12)
    pool.rollback()
    pool.check_conservation()
    assert pool.live_pages() == 0 and len(pool.free) == 8
    # hook disarmed => allocation succeeds
    pool.alloc_hook = None
    pool.admit(0, 12)
    pool.ensure(0, 12)
    assert pool.n_alloc[0] == 3


# ----------------------------------------------------------------------
# Engine lifecycle under faults (slow)
# ----------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_lm():
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return params, cfg


def _prompts(cfg, plens, seed=0):
    key = jax.random.PRNGKey(seed)
    return [jax.random.randint(jax.random.fold_in(key, i), (p,), 0,
                               cfg.vocab) for i, p in enumerate(plens)]


def _assert_drained(eng):
    """Post-run lifecycle invariants: pool accounting balances, nothing
    is stranded, and every page returned to the free list."""
    eng.pool.check_conservation()
    assert len(eng.pool.free) == eng.pool.n_pages
    assert not eng.queue and not eng.chunking
    assert all(a is None for a in eng.active)
    # ITL continuity (PR 10 bugfix): every completion's inter-token
    # gaps pair its tokens — across preempt-resume, recovery replay and
    # speculative multi-token steps alike. A resumed request used to
    # lose its pre-preemption timestamps and report itl_s=[].
    for c in eng.completed:
        assert len(c.itl_s) == max(len(c.tokens) - 1, 0), \
            (c.rid, c.status, len(c.itl_s), len(c.tokens))
        assert all(g >= 0 for g in c.itl_s), (c.rid, c.status)


@pytest.mark.slow
def test_seeded_fault_plan_acceptance(small_lm):
    """ISSUE acceptance: allocation failures + NaN logits + one step
    exception. Every rid reaches exactly one terminal completion with
    the right status, the pool balances, and the surviving engine then
    serves a clean trace bit-identical to a fresh engine's."""
    params, cfg = small_lm
    plens = [3, 9, 6, 12]
    prompts = _prompts(cfg, plens)
    n_new = 6

    # alloc faults are one-shot per tick and only fire on a real page
    # draw: clock 0 is the first admission (guaranteed draw) and at
    # page_size=4 the slot-0 decode crosses a page boundary at clock 2
    plan = FaultPlan.from_specs(Fault("alloc", 0), Fault("alloc", 2),
                                Fault("nan", 4, slot=0), Fault("exc", 6))
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                 paging=PagingConfig(page_size=4), faults=plan)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=n_new))
    done = eng.run()

    # exactly one terminal completion per rid, all statuses legal
    assert sorted(c.rid for c in done) == list(range(len(prompts)))
    assert all(c.status in TERMINAL_STATUSES for c in done)
    # the injected faults actually fired and were survived
    assert eng.stats["alloc_faults"] >= 2
    assert eng.stats["nan_quarantined"] == 1
    assert eng.stats["recoveries"] == 1 and len(eng.errors) == 1
    assert "StepFault" in eng.errors[0]
    # the poisoned slot's rid failed; every other rid finished ok with
    # exact greedy parity (recompute after the step exception is exact)
    failed = [c for c in done if c.status == "failed"]
    assert len(failed) == 1
    for c in done:
        if c.status == "ok":
            want = manual_greedy(params, cfg, prompts[c.rid], n_new, 32)
            assert c.tokens == want, (c.rid, c.tokens, want)
    _assert_drained(eng)

    # the SAME engine instance now serves a clean trace bit-identically
    # to a fresh engine (device state fully rebuilt, no fault residue)
    eng.faults = FaultPlan()
    fresh = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                   paging=PagingConfig(page_size=4))
    for e in (eng, fresh):
        e.completed = []
        for i, p in enumerate(prompts):
            e.submit(Request(rid=100 + i, prompt=p, max_new=n_new))
    got = {c.rid: c for c in eng.run()}
    ref = {c.rid: c for c in fresh.run()}
    assert sorted(got) == sorted(ref)
    for rid in ref:
        assert got[rid].status == ref[rid].status == "ok"
        assert got[rid].tokens == ref[rid].tokens, rid


@pytest.mark.slow
def test_preempt_resume_stream_bit_identical(small_lm):
    """Pool-pressure preemption: the victim's pages roll back, it
    re-enqueues with its produced tokens, recomputes through the
    ordinary prefill path — and its final greedy stream is bit-identical
    to the unpreempted one."""
    params, cfg = small_lm
    plens = [9, 10, 11]
    prompts = _prompts(cfg, plens, seed=3)
    n_new = 8
    # worst = plen + 7 <= 18 -> 3 pages each at page_size=8; a 6-page
    # pool holds two residents, so rid 2 starves at the head until
    # patience preempts the youngest resident
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                 paging=PagingConfig(page_size=8, n_pages=6),
                 preempt_patience=2)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=n_new))
    done = eng.run()
    assert eng.stats["preemptions"] >= 1
    assert eng.stats["recompute_tokens"] > 0
    assert sorted(c.rid for c in done) == [0, 1, 2]
    for c in done:
        assert c.status == "ok", (c.rid, c.status)
        want = manual_greedy(params, cfg, prompts[c.rid], n_new, 32)
        assert c.tokens == want, (c.rid, c.tokens, want)
    _assert_drained(eng)


@pytest.mark.slow
def test_deadline_inversion_preempts_deadline_free_resident(small_lm):
    """A deadlined queue head starved behind deadline-free residents
    preempts the youngest of them immediately (no patience needed)."""
    params, cfg = small_lm
    prompts = _prompts(cfg, [9, 10, 9], seed=5)
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                 paging=PagingConfig(page_size=8, n_pages=6))
    # two deadline-free residents fill the pool...
    eng.submit(Request(rid=0, prompt=prompts[0], max_new=12))
    eng.submit(Request(rid=1, prompt=prompts[1], max_new=12))
    # ...and a deadlined head arrives behind them
    eng.submit(Request(rid=2, prompt=prompts[2], max_new=4,
                       deadline_s=30.0))
    done = eng.run()
    assert eng.stats["preemptions"] >= 1
    by_rid = {c.rid: c for c in done}
    assert sorted(by_rid) == [0, 1, 2]
    # the deadlined request got in and finished well before its deadline
    assert by_rid[2].status == "ok"
    # the victim still completed with an exact stream after recompute
    for rid, n_new in ((0, 12), (1, 12), (2, 4)):
        assert by_rid[rid].status == "ok"
        want = manual_greedy(params, cfg, prompts[rid], n_new, 32)
        assert by_rid[rid].tokens == want, rid
    _assert_drained(eng)


@pytest.mark.slow
def test_nan_quarantine_isolates_poisoned_slot(small_lm):
    """All-slot poisoning retires every live request as `failed`; the
    engine stays serviceable and a clean rerun is exact."""
    params, cfg = small_lm
    prompts = _prompts(cfg, [5, 7], seed=8)
    plan = FaultPlan.from_specs(Fault("nan", 2))       # slot=None => all
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                 paging=PagingConfig(page_size=8), faults=plan)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=8))
    done = eng.run()
    assert sorted(c.rid for c in done) == [0, 1]
    assert all(c.status == "failed" for c in done)
    assert eng.stats["nan_quarantined"] == 2
    # a quarantined request keeps the tokens it produced before the hit
    assert all(0 < len(c.tokens) < 8 for c in done)
    _assert_drained(eng)
    eng.faults = FaultPlan()
    eng.submit(Request(rid=9, prompt=prompts[0], max_new=6))
    (c9,) = [c for c in eng.run() if c.rid == 9]
    assert c9.status == "ok"
    assert c9.tokens == manual_greedy(params, cfg, prompts[0], 6, 32)


@pytest.mark.slow
def test_step_exception_recovery_replays_live_prompts(small_lm):
    """A mid-step exception invalidates the donated cache; the recovery
    boundary rebuilds device state and host-mirror-replays the live
    prompts — final streams stay exact."""
    params, cfg = small_lm
    prompts = _prompts(cfg, [3, 9, 6], seed=11)
    n_new = 6
    plan = FaultPlan.from_specs(Fault("exc", 3))
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                 paging=PagingConfig(page_size=8), faults=plan)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=n_new))
    done = eng.run()
    assert eng.stats["recoveries"] == 1
    assert eng.stats["recompute_tokens"] > 0
    assert sorted(c.rid for c in done) == [0, 1, 2]
    for c in done:
        assert c.status == "ok", (c.rid, c.status)
        want = manual_greedy(params, cfg, prompts[c.rid], n_new, 32)
        assert c.tokens == want, (c.rid, c.tokens, want)
    _assert_drained(eng)


@pytest.mark.slow
def test_cancel_and_deadline_statuses(small_lm):
    params, cfg = small_lm
    prompts = _prompts(cfg, [5, 6, 7, 8], seed=13)
    eng = Engine(params, cfg, n_slots=1, max_len=32, eos_id=-1,
                 paging=PagingConfig(page_size=8))
    eng.submit(Request(rid=0, prompt=prompts[0], max_new=4))
    eng.submit(Request(rid=1, prompt=prompts[1], max_new=4))
    # an immediately-expired deadline: swept before it ever admits
    eng.submit(Request(rid=2, prompt=prompts[2], max_new=4,
                       deadline_s=0.0))
    eng.submit(Request(rid=3, prompt=prompts[3], max_new=4))
    # cancel one queued request before the loop even starts
    assert eng.cancel(1)
    assert not eng.cancel(1)         # already terminal
    assert not eng.cancel(42)        # unknown rid
    done = eng.run()
    by_rid = {c.rid: c for c in done}
    assert sorted(by_rid) == [0, 1, 2, 3]
    assert by_rid[1].status == "cancelled" and by_rid[1].tokens == []
    assert by_rid[2].status == "deadline"
    assert by_rid[0].status == "ok" and by_rid[3].status == "ok"
    _assert_drained(eng)


@pytest.mark.slow
def test_max_steps_flushes_outstanding_work(small_lm):
    """Regression (satellite): run(max_steps) used to silently drop
    queued and mid-flight requests. Now everything outstanding gets a
    terminal `preempted_requeued` completion carrying its tokens so
    far, the engine stays clean, and resubmission finishes exactly."""
    params, cfg = small_lm
    prompts = _prompts(cfg, [5, 9], seed=17)
    eng = Engine(params, cfg, n_slots=1, max_len=32, eos_id=-1,
                 paging=PagingConfig(page_size=8))
    eng.submit(Request(rid=0, prompt=prompts[0], max_new=10))
    eng.submit(Request(rid=1, prompt=prompts[1], max_new=10))
    done = eng.run(max_steps=3)
    by_rid = {c.rid: c for c in done}
    assert sorted(by_rid) == [0, 1]  # NOTHING dropped
    assert by_rid[0].status == "preempted_requeued"
    assert 0 < len(by_rid[0].tokens) < 10     # partial stream attached
    assert by_rid[1].status == "preempted_requeued"
    assert by_rid[1].tokens == []             # never admitted
    _assert_drained(eng)
    # the engine is still serviceable; a resubmitted request is exact
    eng.completed = []
    eng.submit(Request(rid=0, prompt=prompts[0], max_new=10))
    (c0,) = eng.run()
    assert c0.status == "ok"
    assert c0.tokens == manual_greedy(params, cfg, prompts[0], 10, 32)


@pytest.mark.slow
def test_unserviceable_request_fails_instead_of_wedging(small_lm):
    """A head needing more pages than the pool HOLDS retires `failed`
    (it could never admit); the queue behind it still serves."""
    params, cfg = small_lm
    prompts = _prompts(cfg, [24, 5], seed=19)
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                 paging=PagingConfig(page_size=8, n_pages=2))
    eng.submit(Request(rid=0, prompt=prompts[0], max_new=8))   # 31 rows
    eng.submit(Request(rid=1, prompt=prompts[1], max_new=4))   # fits
    done = eng.run()
    by_rid = {c.rid: c for c in done}
    assert by_rid[0].status == "failed"
    assert by_rid[1].status == "ok"
    assert by_rid[1].tokens == manual_greedy(params, cfg, prompts[1],
                                             4, 32)
    _assert_drained(eng)


@pytest.mark.slow
def test_chunked_prefill_survives_faults(small_lm):
    """Alloc faults + a step exception landing while prompts are
    mid-chunk: panels retry / replay and streams stay exact."""
    params, cfg = small_lm
    prompts = _prompts(cfg, [40, 20], seed=23)
    n_new = 4
    plan = FaultPlan.from_specs(Fault("alloc", 1), Fault("exc", 2))
    eng = Engine(params, cfg, n_slots=2, max_len=64, eos_id=-1,
                 paging=PagingConfig(page_size=8, prefill_chunk=16),
                 faults=plan)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=n_new))
    done = eng.run()
    assert sorted(c.rid for c in done) == [0, 1]
    for c in done:
        assert c.status == "ok", (c.rid, c.status)
        want = manual_greedy(params, cfg, prompts[c.rid], n_new, 64)
        assert c.tokens == want, (c.rid, c.tokens, want)
    assert eng.stats["recoveries"] == 1
    _assert_drained(eng)


# ----------------------------------------------------------------------
# Chaos suite (hypothesis; pin HYPOTHESIS_SEED in CI for replay)
# ----------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @pytest.mark.chaos
    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_chaos_lifecycle_invariants(small_lm, seed):
        """Random fault schedules (allocation failures, NaN logits, step
        exceptions, slow steps) against the lifecycle invariants: no
        lost rids, one terminal completion each, page conservation, and
        a serviceable engine afterwards."""
        params, cfg = small_lm
        plan = FaultPlan.random(seed, 24, n_slots=2, p_alloc=0.25,
                                p_nan=0.1, p_exc=0.08, p_slow=0.05,
                                slow_s=1e-4)
        plens = [3, 9, 6, 12, 5]
        prompts = _prompts(cfg, plens, seed=seed % 1000)
        eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                     paging=PagingConfig(page_size=8, n_pages=6),
                     faults=plan, preempt_patience=3)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new=5))
        done = eng.run()
        # no lost rids, exactly one terminal completion per rid
        assert sorted(c.rid for c in done) == list(range(len(plens)))
        assert all(c.status in TERMINAL_STATUSES for c in done)
        # free+live conservation, no double allocation, nothing stranded
        _assert_drained(eng)
        # engine remains serviceable after every injected fault
        eng.faults = FaultPlan()
        eng.submit(Request(rid=99, prompt=prompts[0], max_new=4))
        (c99,) = [c for c in eng.run() if c.rid == 99]
        assert c99.status == "ok"
        assert c99.tokens == manual_greedy(params, cfg, prompts[0], 4, 32)
