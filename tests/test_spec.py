"""Self-speculative decoding (PR 10): drafter units, k-ladder, exact
greedy parity through the paged verify path, and the sampling-boundary
bugfix sweep.

Fast section — the prompt-lookup drafter and ``spec_ladder`` (pure
host numpy, no model), plus the ``filter_logits`` / temperature-
boundary regressions. Slow section — engine-level parity: greedy
streams must be bit-identical spec-on vs spec-off on the dense-oracle
archs (global attention AND sliding-window rings, where a sloppy
verify would clobber ring rows with rejected drafts), across
preempt-resume, with the verify compile count held to the documented
ladder.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import manual_greedy

from repro.configs import REDUCED
from repro.core.types import PagingConfig
from repro.models import lm
from repro.serve import sampling, spec
from repro.serve.engine import Engine, Request
from repro.serve.paging import bucket_for, spec_ladder

# ----------------------------------------------------------------------
# drafter + ladder units (fast)
# ----------------------------------------------------------------------


def test_propose_prefers_longest_ngram():
    # tail [7, 8] matches at position 2 (n=2); tail [8] alone also
    # matches at 3 — the longer context must win
    hist = np.asarray([1, 7, 8, 9, 5, 7, 8], np.int32)
    out = spec.propose(hist, 3)
    assert out.tolist() == [9, 5, 7]


def test_propose_most_recent_match_wins():
    # tail [3] matches at positions 0 and 2; the drafter must copy the
    # continuation of the LATEST occurrence (local context beats stale)
    hist = np.asarray([3, 4, 3, 6, 3], np.int32)
    out = spec.propose(hist, 2)
    assert out.tolist() == [6, 3]


def test_propose_truncates_at_history_end_and_k():
    hist = np.asarray([5, 6, 5, 6, 5], np.int32)
    assert spec.propose(hist, 8).tolist() == [6, 5]   # runs off the end
    assert spec.propose(hist, 1).tolist() == [6]      # k caps it


def test_propose_no_match_and_degenerate_inputs():
    assert spec.propose(np.asarray([1, 2, 3, 4], np.int32), 4).size == 0
    assert spec.propose(np.asarray([9], np.int32), 4).size == 0
    assert spec.propose(np.asarray([], np.int32), 4).size == 0
    assert spec.propose(np.asarray([1, 1, 2], np.int32), 0).size == 0


def test_propose_repetitive_loop_fills_k():
    phrase = np.asarray([11, 12, 13, 14], np.int32)
    hist = np.tile(phrase, 4)
    out = spec.propose(hist, 4)
    # the loop continues exactly: after ...13, 14 comes 11, 12, 13, 14
    assert out.tolist() == [11, 12, 13, 14]


def test_spec_ladder_is_pow2_and_covers_k():
    assert spec_ladder(0) == []
    assert spec_ladder(1) == [1]
    assert spec_ladder(4) == [1, 2, 4]
    assert spec_ladder(5) == [1, 2, 4, 8]
    for k in range(1, 33):
        ladder = spec_ladder(k)
        assert ladder[-1] >= k
        assert all(b == 1 << i for i, b in enumerate(ladder))
        # every reachable draft length buckets into the ladder
        for d in range(1, k + 1):
            assert bucket_for(d, ladder) in ladder


# ----------------------------------------------------------------------
# sampling-boundary regressions (fast)
# ----------------------------------------------------------------------


def test_temperature_boundary_matches_greedy():
    """Regression (PR 10 bugfix): at t=1e-7 — below GREEDY_EPS but
    nonzero — the fallback threshold and the divide clamp used to
    disagree, so a row could divide by a denormal-scale temperature
    (inf/NaN logits) yet miss the greedy fallback. Any t below the eps
    must be exact greedy."""
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.3, 5.0, 1.0, -2.0],
                          [2.0, -1.0, 0.5, 1.9]])
    want = sampling.greedy(logits)
    for t in (0.0, 1e-30, 1e-7, sampling.GREEDY_EPS / 2):
        got = sampling.sample(logits, key, temperature=t)
        assert jnp.array_equal(got, want), t
        assert bool(jnp.all(jnp.isfinite(
            logits / jnp.maximum(jnp.asarray(t), sampling.GREEDY_EPS))))
    # per-row mixing: a greedy row rides along with a hot sampled row
    temps = jnp.asarray([1e-7, 1.0])
    got = sampling.sample(logits, key, temperature=temps)
    assert int(got[0]) == int(want[0])


def test_filter_logits_on_panel_shapes():
    """filter_logits must accept the verify path's (B, S, V) panels,
    not just (B, V) rows, and filter each row independently."""
    key = jax.random.PRNGKey(1)
    panel = jax.random.normal(key, (2, 3, 8))
    out = sampling.filter_logits(panel, top_k=2, top_p=1.0)
    assert out.shape == panel.shape
    kept = jnp.isfinite(out).sum(axis=-1)
    assert bool(jnp.all(kept == 2))
    flat = sampling.filter_logits(panel.reshape(6, 8), top_k=2,
                                  top_p=1.0)
    assert jnp.array_equal(out.reshape(6, 8), flat)


# ----------------------------------------------------------------------
# engine parity (slow)
# ----------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_lm():
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg,
                           dtype=jnp.float32)
    return params, cfg


def _prompts(cfg, plens, seed=0, repetitive=False):
    key = jax.random.PRNGKey(seed)
    out = []
    for i, p in enumerate(plens):
        if repetitive:
            phrase = np.asarray(jax.random.randint(
                jax.random.fold_in(key, i), (3,), 0, cfg.vocab))
            out.append(jnp.asarray(np.tile(phrase, -(-p // 3))[:p]))
        else:
            out.append(jax.random.randint(jax.random.fold_in(key, i),
                                          (p,), 0, cfg.vocab))
    return out


def _drive(params, cfg, prompts, k, n_new, *, max_len=48, n_pages=0,
           patience=None, temperature=0.0, seed=0):
    eng = Engine(params, cfg, n_slots=2, max_len=max_len, eos_id=-1,
                 temperature=temperature, seed=seed,
                 paging=PagingConfig(page_size=8, n_pages=n_pages,
                                     speculate_k=k),
                 preempt_patience=patience)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=n_new))
    done = eng.run()
    eng.pool.check_conservation()
    assert len(eng.pool.free) == eng.pool.n_pages
    for c in done:
        assert len(c.itl_s) == max(len(c.tokens) - 1, 0), c.rid
    return eng, {c.rid: c for c in done}


@pytest.mark.slow
def test_spec_greedy_parity_vs_oracle(small_lm):
    """Greedy streams spec-on == spec-off == the dense-cache oracle, on
    repetitive prompts (drafts accept) AND incompressible ones (every
    draft rejects — the rollback path runs constantly)."""
    params, cfg = small_lm
    n_new = 8
    for repetitive in (False, True):
        prompts = _prompts(cfg, [7, 10, 13], seed=2,
                           repetitive=repetitive)
        eng_on, on = _drive(params, cfg, prompts, 4, n_new)
        _, off = _drive(params, cfg, prompts, 0, n_new)
        for rid, p in enumerate(prompts):
            want = manual_greedy(params, cfg, p, n_new, 48)
            assert off[rid].tokens == want, (repetitive, rid)
            assert on[rid].tokens == want, (repetitive, rid)
        if repetitive:
            assert eng_on.stats["spec_accepted"] > 0
        # the verify programs stay within the documented k-ladder
        assert eng_on.compile_counts()["spec"] <= len(spec_ladder(4))


@pytest.mark.slow
def test_spec_parity_sliding_window(small_lm):
    """Sliding-window rings are where a sloppy verify corrupts state:
    a rejected draft row written into the ring would overwrite a live
    token slot (ring position = pos % window). Greedy parity spec-on
    vs off on the gemma3-style local-attention arch proves rejected
    rows never land."""
    del small_lm
    cfg = REDUCED["gemma3-27b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(3), cfg,
                           dtype=jnp.float32)
    # decode far enough past local_window=16 to wrap the ring
    prompts = _prompts(cfg, [6, 9], seed=4, repetitive=True)
    _, on = _drive(params, cfg, prompts, 4, 24, max_len=64)
    _, off = _drive(params, cfg, prompts, 0, 24, max_len=64)
    for rid in off:
        assert on[rid].tokens == off[rid].tokens, rid


@pytest.mark.slow
def test_spec_parity_across_preempt_resume(small_lm):
    """A starved pool forces preemption mid-speculation: the victim's
    pages (draft tails included) roll back, it resumes through prefill,
    and the final streams still match spec-off exactly."""
    params, cfg = small_lm
    prompts = _prompts(cfg, [9, 10, 11], seed=5, repetitive=True)
    n_new = 8
    # 6 pages of 8 hold two of three residents (worst ~3 pages each)
    eng_on, on = _drive(params, cfg, prompts, 4, n_new, max_len=32,
                        n_pages=6, patience=2)
    eng_off, off = _drive(params, cfg, prompts, 0, n_new, max_len=32,
                          n_pages=6, patience=2)
    assert eng_on.stats["preemptions"] >= 1
    for rid in off:
        assert on[rid].status == off[rid].status == "ok"
        assert on[rid].tokens == off[rid].tokens, rid
        want = manual_greedy(params, cfg, prompts[rid], n_new, 32)
        assert on[rid].tokens == want, rid


@pytest.mark.slow
def test_spec_respects_max_new_and_max_len(small_lm):
    """Budget caps: a fully accepted draft never emits past max_new,
    and the length retirement fires at the same token count as plain
    decode (the last allowed row is the only one that can reach
    max_len - 1)."""
    params, cfg = small_lm
    prompts = _prompts(cfg, [12], seed=6, repetitive=True)
    for n_new, max_len in ((3, 48), (8, 18)):
        _, on = _drive(params, cfg, prompts, 4, n_new, max_len=max_len)
        _, off = _drive(params, cfg, prompts, 0, n_new, max_len=max_len)
        assert on[0].tokens == off[0].tokens
        assert on[0].status == off[0].status
        assert len(on[0].tokens) <= n_new


@pytest.mark.slow
def test_top_k_top_p_plumbing(small_lm):
    """Engine-level top_k/top_p: greedy rows stay bit-identical
    whatever the filter (the static filter applies only to sampled
    rows), and sampled rows with a tight filter stay inside the kept
    set. One engine => one decode program regardless of the knobs."""
    params, cfg = small_lm
    prompts = _prompts(cfg, [7, 9], seed=7)
    n_new = 6
    _, plain = _drive(params, cfg, prompts, 0, n_new)
    eng = Engine(params, cfg, n_slots=2, max_len=48, eos_id=-1,
                 temperature=0.0, top_k=3, top_p=0.9,
                 paging=PagingConfig(page_size=8))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=n_new))
    got = {c.rid: c for c in eng.run()}
    for rid in plain:     # greedy rows ignore the filter bit-exactly
        assert got[rid].tokens == plain[rid].tokens, rid
    assert eng.compile_counts()["step"] == 1


def test_spec_config_rejections(small_lm):
    """speculate_k needs a bucketing-capable arch (the verify panel is
    a chunk shape) and full-width tables (a width ladder would multiply
    the verify k-ladder against it — the exact compile-bound blowup the
    PR 9 auditor exists to catch)."""
    params, cfg = small_lm
    with pytest.raises(ValueError, match="table_width_bucketing"):
        Engine(params, cfg, n_slots=2, max_len=48, eos_id=-1,
               paging=PagingConfig(page_size=8, speculate_k=2,
                                   table_width_bucketing=True))
    rcfg = REDUCED["rwkv6-3b"]()
    rparams, _ = lm.init_lm(jax.random.PRNGKey(0), rcfg,
                            dtype=jnp.float32)
    with pytest.raises(ValueError, match="speculat"):
        Engine(rparams, rcfg, n_slots=2, max_len=48, eos_id=-1,
               paging=PagingConfig(page_size=8, speculate_k=2))
