"""Chunked recurrences vs naive per-step oracles (Mamba2 SSD, RWKV6 WKV)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba2, rwkv6


def _r(rng, shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@pytest.mark.parametrize("sl,chunk", [(50, 16), (64, 64), (17, 128)])
def test_ssd_chunked_matches_ref(rng, sl, chunk):
    B, H, P, N = 2, 3, 8, 16
    xh = _r(rng, (B, sl, H, P))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, sl, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.1, 2.0, (H,)), jnp.float32)
    Bm, Cm = _r(rng, (B, sl, N)), _r(rng, (B, sl, N))
    y1, s1 = mamba2.ssd_chunked(xh, dt, a, Bm, Cm, chunk=chunk)
    y2, s2 = mamba2.ssd_ref(xh, dt, a, Bm, Cm)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


def test_ssd_state_continuation(rng):
    B, H, P, N, sl = 1, 2, 8, 8, 48
    xh = _r(rng, (B, sl, H, P))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, sl, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.1, 2.0, (H,)), jnp.float32)
    Bm, Cm = _r(rng, (B, sl, N)), _r(rng, (B, sl, N))
    y_full, s_full = mamba2.ssd_chunked(xh, dt, a, Bm, Cm, chunk=16)
    ya, sa = mamba2.ssd_chunked(xh[:, :20], dt[:, :20], a, Bm[:, :20],
                                Cm[:, :20], chunk=16)
    yb, sb = mamba2.ssd_chunked(xh[:, 20:], dt[:, 20:], a, Bm[:, 20:],
                                Cm[:, 20:], chunk=16, s0=sa)
    np.testing.assert_allclose(jnp.concatenate([ya, yb], 1), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sb, s_full, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("sl", [45, 16, 7])
def test_wkv_chunked_matches_ref(rng, sl):
    B, H, P = 2, 2, 8
    r = _r(rng, (B, sl, H, P))
    k = _r(rng, (B, sl, H, P))
    v = _r(rng, (B, sl, H, P))
    lw = jnp.clip(-jnp.exp(_r(rng, (B, sl, H, P))), -rwkv6.CLAMP, -1e-6)
    u = _r(rng, (H, P))
    y1, s1 = rwkv6.wkv_chunked(r, k, v, lw, u)
    y2, s2 = rwkv6.wkv_ref(r, k, v, lw, u)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


def test_wkv_state_continuation(rng):
    B, H, P, sl = 1, 2, 8, 40
    r, k, v = (_r(rng, (B, sl, H, P)) for _ in range(3))
    lw = jnp.clip(-jnp.exp(_r(rng, (B, sl, H, P))), -rwkv6.CLAMP, -1e-6)
    u = _r(rng, (H, P))
    y_full, _ = rwkv6.wkv_ref(r, k, v, lw, u)
    ya, sa = rwkv6.wkv_chunked(r[:, :20], k[:, :20], v[:, :20],
                               lw[:, :20], u)
    yb, _ = rwkv6.wkv_chunked(r[:, 20:], k[:, 20:], v[:, 20:],
                              lw[:, 20:], u, s0=sa)
    np.testing.assert_allclose(jnp.concatenate([ya, yb], 1), y_full,
                               rtol=2e-4, atol=2e-4)


def test_wkv_decay_extremes(rng):
    """Clamped decay boundaries stay finite and match the oracle."""
    B, H, P, sl = 1, 1, 4, 33
    r, k, v = (_r(rng, (B, sl, H, P)) for _ in range(3))
    lw = jnp.full((B, sl, H, P), -rwkv6.CLAMP)
    u = _r(rng, (H, P))
    y1, _ = rwkv6.wkv_chunked(r, k, v, lw, u)
    y2, _ = rwkv6.wkv_ref(r, k, v, lw, u)
    assert bool(jnp.all(jnp.isfinite(y1)))
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
