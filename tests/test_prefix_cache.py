"""Radix-tree prefix cache + copy-on-write refcounted pages (PR 8).

The fast tier exercises the host side in isolation: radix match /
insert / LRU eviction against a bare PagePool, the refcount life of a
shared page, COW resolution (private copy vs in-place claim), and the
engine's config gates (chunked prefill required, sliding-window archs
silently opt out). The slow tier drives the full engine: cache-on
greedy streams must be bit-identical to the dense oracle AND to the
cache-off engine across hit / miss / partial-page-COW admissions, a
duplicate prompt submitted the same step must defer-then-share instead
of racing a private copy, a cache-hit slot must survive pool-pressure
preemption with an exact stream, the Sarathi token budget must defer
chunks without changing tokens, and tree eviction under pool pressure
must keep every request terminal.
"""
import jax
import jax.numpy as jnp
import pytest
from conftest import manual_greedy

from repro.configs import REDUCED
from repro.core.types import PagingConfig
from repro.models import lm
from repro.serve.engine import Engine, Request
from repro.serve.paging import PagePool
from repro.serve.prefix_cache import PrefixCache


# ----------------------------------------------------------------------
# Radix tree + refcounted pool, no model (fast)
# ----------------------------------------------------------------------


def _pool_with_cache(n_pages=8, ps=4, n_slots=2, max_pages=8):
    pool = PagePool(n_pages, ps, n_slots, max_pages)
    cache = PrefixCache(pool)
    pool.reclaimer = cache
    return pool, cache


def test_radix_insert_match_partial():
    pool, cache = _pool_with_cache()
    pool.admit(0, 12)
    pool.ensure(0, 12)                        # 3 pages of 4 tokens
    prompt = list(range(12))
    assert cache.insert(prompt, pool.tables[0]) == 3
    pages = [int(p) for p in pool.tables[0, :3]]
    # tree reference on top of the slot's table mapping
    assert all(pool.refs[p] == 2 for p in pages)
    # exact replay: every full page matches, nothing partial
    assert cache.match(prompt) == (pages, None)
    # trailing tokens past the cached pages don't confuse the walk
    assert cache.match(prompt + [77]) == (pages, None)
    # divergence inside page 2: two full pages + a 2-token partial
    m, partial = cache.match(prompt[:10] + [99, 98])
    assert m == pages[:2] and partial == (pages[2], 2)
    # divergence inside page 0: nothing full, partial from the root
    m, partial = cache.match([0, 1, 99, 98])
    assert m == [] and partial == (pages[0], 2)
    # a cold prompt misses entirely
    assert cache.match([50, 51, 52, 53]) == ([], None)
    # re-inserting the same prompt adds nothing and keeps incumbents
    pool.admit(1, 12)
    pool.ensure(1, 12)
    assert cache.insert(prompt, pool.tables[1]) == 0
    assert cache.match(prompt)[0] == pages
    pool.check_conservation()


def test_radix_eviction_lru_and_referenced_pages_pinned():
    pool, cache = _pool_with_cache(n_pages=8, ps=4)
    prompt_a = list(range(12))                # 3 pages
    prompt_b = prompt_a[:4] + [90 + i for i in range(8)]  # shares page 0
    pool.admit(0, 12)
    pool.ensure(0, 12)
    cache.insert(prompt_a, pool.tables[0])
    pool.admit(1, 12)
    pool.ensure(1, 12)
    cache.insert(prompt_b, pool.tables[1])
    b_leaf = int(pool.tables[1, 2])
    # while the slots still map the pages nothing is evictable, and
    # reclaim must not free a referenced page
    assert cache.evictable() == 0
    assert cache.reclaim(10) == 0
    # slot 0 retires: a's two deep nodes become reclaimable, but the
    # shared root stays pinned — slot 1 still maps descendants of it,
    # and a pinned descendant blocks the whole ancestor chain
    pool.release(0)
    assert cache.evictable() == 2
    pool.release(1)
    assert pool.live_pages() == 0
    # every node's subtree now holds only tree references, so the
    # whole 5-node tree (shared page 0 + two 2-node branches) counts
    # as cascade-reclaimable headroom
    assert cache.evictable() == 5
    assert pool.available() == len(pool.free) + 5
    # LRU: touch branch a, then a single eviction takes b's tip
    cache.match(prompt_a)
    free0 = len(pool.free)
    assert cache.reclaim(1) == 1
    assert cache.evictions == 1
    assert b_leaf in pool.free and len(pool.free) == free0 + 1
    assert cache.match(prompt_a)[0] != []
    # cascade: draining the rest frees every remaining node exactly once
    assert cache.reclaim(10) == 4
    assert cache.match(prompt_a) == ([], None)
    assert len(pool.free) == pool.n_pages
    pool.check_conservation()


def test_pool_cow_private_copy_and_in_place():
    pool, _ = _pool_with_cache(n_pages=6, ps=4)
    pool.admit(0, 8)
    pool.ensure(0, 8)
    donor = [int(p) for p in pool.tables[0, :2]]
    # slot 1 maps both donor pages, the tail COW-pending
    pool.admit(1, 8)
    pool.map_shared(1, donor[:1])
    pool.map_shared(1, donor[1:], cow_tail=True)
    assert pool.cow_idx[1] == 1
    assert all(pool.refs[p] == 2 for p in donor)
    # both mappers live: resolving COW draws a private page
    src, dst = pool.cow(1, 1)
    assert src == donor[1] and dst != src
    assert pool.refs[src] == 1 and pool.refs[dst] == 1
    assert int(pool.tables[1, 1]) == dst and pool.cow_idx[1] == -1
    pool.check_conservation()
    pool.release(1)
    # sole-mapper case: slot 1 re-shares, slot 0 retires first, so the
    # pending page has refcount 1 at resolution -> claimed in place
    pool.admit(1, 8)
    pool.map_shared(1, donor[:1])
    pool.map_shared(1, [int(pool.tables[0, 1])], cow_tail=True)
    pool.release(0)
    free0 = len(pool.free)
    src, dst = pool.cow(1, 1)
    assert src == dst and len(pool.free) == free0
    assert pool.cow_idx[1] == -1
    pool.check_conservation()


def test_prefix_cache_config_gates():
    key = jax.random.PRNGKey(0)
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    # cache hits replay the suffix through the chunk program: without
    # chunked prefill the feature cannot work, so it's a hard error
    with pytest.raises(ValueError):
        Engine(params, cfg, n_slots=2, max_len=64,
               paging=PagingConfig(prefix_cache=True))
    eng = Engine(params, cfg, n_slots=2, max_len=64,
                 paging=PagingConfig(prefill_chunk=16, prefix_cache=True))
    assert eng.prefix_cache is not None
    assert eng.pool.reclaimer is eng.prefix_cache
    # sliding-window archs silently opt out: a ring write through a
    # shared page would clobber every other mapper's cached prefix
    gcfg = REDUCED["gemma3-27b"]()
    gparams, _ = lm.init_lm(jax.random.PRNGKey(1), gcfg,
                            dtype=jnp.float32)
    geng = Engine(gparams, gcfg, n_slots=2, max_len=64,
                  paging=PagingConfig(prefill_chunk=16,
                                      prefix_cache=True))
    assert geng.prefix_cache is None


# ----------------------------------------------------------------------
# Full engine: parity, races, preemption, budget, eviction (slow)
# ----------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_lm():
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return params, cfg


def _run(params, cfg, prompts, *, prefix, n_new=5, chunk=16, page_size=8,
         max_len=96, n_slots=2, n_pages=0, patience=None, budget=0,
         eng=None):
    if eng is None:
        eng = Engine(params, cfg, n_slots=n_slots, max_len=max_len,
                     eos_id=-1,
                     paging=PagingConfig(page_size=page_size,
                                         n_pages=n_pages,
                                         prefill_chunk=chunk,
                                         prefix_cache=prefix,
                                         prefill_token_budget=budget),
                     preempt_patience=patience)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=n_new))
    done = eng.run()
    return eng, {c.rid: c for c in done}


def _drained(eng):
    """Post-run conservation: no slot maps pages, only the tree holds
    references, and free + referenced covers the whole pool."""
    eng.pool.check_conservation()
    assert eng.pool.live_pages() == 0
    held = int((eng.pool.refs > 0).sum())
    assert len(eng.pool.free) + held == eng.pool.n_pages


@pytest.mark.slow
def test_hit_miss_partial_cow_streams_bit_identical(small_lm):
    """The acceptance matrix: a donor miss populates the tree, a full
    hit maps every prompt page, a mid-page divergence takes the
    partial-page COW path, an unrelated prompt misses cold, and an
    exact resubmission of a fully cached page-aligned prompt demotes
    its last page to COW (the hit is capped at plen-1 so at least one
    suffix token runs). Every stream must equal the dense oracle and
    the cache-off engine token for token."""
    params, cfg = small_lm
    key = jax.random.PRNGKey(3)
    sys_p = jax.random.randint(key, (40,), 0, cfg.vocab)  # 5 full pages

    def tail(i, n):
        return jax.random.randint(jax.random.fold_in(key, i), (n,), 0,
                                  cfg.vocab)

    prompts = [
        jnp.concatenate([sys_p, tail(1, 8)]),        # miss: the donor
        jnp.concatenate([sys_p, tail(2, 8)]),        # full 40-token hit
        jnp.concatenate([sys_p[:36], tail(3, 12)]),  # partial page: COW
        tail(4, 24),                                 # cold miss
        sys_p,                                       # capped hit: COW
    ]
    n_new = 5
    eng_on, on = _run(params, cfg, prompts, prefix=True, n_new=n_new)
    eng_off, off = _run(params, cfg, prompts, prefix=False, n_new=n_new)
    assert sorted(on) == list(range(len(prompts)))
    for i, p in enumerate(prompts):
        want = manual_greedy(params, cfg, p, n_new, 96)
        assert on[i].tokens == want, (i, on[i].tokens, want)
        assert off[i].tokens == want, (i, off[i].tokens, want)
    assert eng_on.stats["prefix_hits"] >= 2
    assert eng_on.stats["prefix_hit_tokens"] >= 40
    # at least one admission crossed the COW seam (private copy or
    # in-place claim), and the cache-off engine crossed none
    assert (eng_on.stats["cow_copies"]
            + eng_on.stats["cow_in_place"]) >= 1
    assert eng_off.stats["prefix_hits"] == 0
    assert eng_off.stats["cow_copies"] == 0
    # queue wait is a prefix of TTFT, never larger
    for c in on.values():
        assert 0.0 <= c.queue_s <= c.ttft_s + 1e-9
    # suffix chunks stay on the ladder at or below the chunk size
    assert all(s <= 16 for s in eng_on._chunk_shapes)
    _drained(eng_on)


@pytest.mark.slow
def test_duplicate_prompt_same_step_defers_then_shares(small_lm):
    """The admission race: two identical prompts in the queue the same
    step. The second must NOT recompute a private copy in parallel —
    it defers until the first activates, then admits as a hit on the
    pages the first just inserted."""
    params, cfg = small_lm
    p = jax.random.randint(jax.random.PRNGKey(7), (40,), 0, cfg.vocab)
    eng, by_rid = _run(params, cfg, [p, p], prefix=True, n_new=4)
    want = manual_greedy(params, cfg, p, 4, 96)
    assert by_rid[0].tokens == want
    assert by_rid[1].tokens == want
    assert eng.stats["share_deferrals"] >= 1
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["prefix_hit_tokens"] >= 32
    _drained(eng)


@pytest.mark.slow
def test_preempt_resume_of_cache_hit_slot(small_lm):
    """Pool-pressure preemption of slots admitted through the hit path:
    release derefs the shared pages (the tree keeps them alive), the
    victim re-enqueues, re-matches the same pages on re-admission, and
    its final greedy stream is still bit-identical."""
    params, cfg = small_lm
    key = jax.random.PRNGKey(9)
    base = jax.random.randint(key, (8,), 0, cfg.vocab)   # one full page
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                 paging=PagingConfig(page_size=8, n_pages=6,
                                     prefill_chunk=16, prefix_cache=True),
                 preempt_patience=2)
    # warm the tree with a donor, then drain it
    eng.submit(Request(rid=10, prompt=base, max_new=1))
    eng.run()
    eng.completed.clear()
    assert eng.prefix_cache.match(base)[0] != []
    # worst = plen + 7 <= 18 -> 3 pages each; page 0 shared via the
    # tree, so two residents hold 5 unique pages of 6 and rid 2 starves
    # at the head until patience preempts the youngest resident
    plens = [9, 10, 11]
    prompts = [jnp.concatenate([base, jax.random.randint(
        jax.random.fold_in(key, i), (n - 8,), 0, cfg.vocab)])
        for i, n in enumerate(plens)]
    n_new = 8
    _, by_rid = _run(params, cfg, prompts, prefix=True, n_new=n_new,
                     eng=eng)
    assert eng.stats["preemptions"] >= 1
    assert eng.stats["prefix_hits"] >= 3     # victim re-hits on resume
    assert sorted(by_rid) == [0, 1, 2]
    for rid, c in by_rid.items():
        assert c.status == "ok", (rid, c.status)
        want = manual_greedy(params, cfg, prompts[rid], n_new, 32)
        assert c.tokens == want, (rid, c.tokens, want)
    _drained(eng)


@pytest.mark.slow
def test_prefill_token_budget_defers_chunks_not_tokens(small_lm):
    """Sarathi-style budget: with two 48-token prompts chunking
    concurrently and a 16-token/step cap, younger slots defer chunks
    (the oldest always advances, so no starvation) — and the streams
    are unchanged."""
    params, cfg = small_lm
    key = jax.random.PRNGKey(11)
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (48,), 0,
                                  cfg.vocab) for i in range(2)]
    n_new = 4
    eng, by_rid = _run(params, cfg, prompts, prefix=True, n_new=n_new,
                       budget=16)
    for i, p in enumerate(prompts):
        want = manual_greedy(params, cfg, p, n_new, 96)
        assert by_rid[i].tokens == want, (i, by_rid[i].tokens, want)
    assert eng.stats["budget_deferred_chunks"] >= 1
    _drained(eng)


@pytest.mark.slow
def test_tree_eviction_under_pool_pressure(small_lm):
    """Six disjoint prompts through a pool that cannot hold the tree
    and two residents at once: admission reclaims LRU branches instead
    of deadlocking, every request completes, and the allocator stays
    conserved."""
    params, cfg = small_lm
    key = jax.random.PRNGKey(13)
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (32,), 0,
                                  cfg.vocab) for i in range(6)]
    eng, by_rid = _run(params, cfg, prompts, prefix=True, n_new=4,
                       page_size=8, n_pages=10, max_len=48, n_slots=2)
    assert sorted(by_rid) == list(range(6))
    assert all(c.status == "ok" for c in by_rid.values())
    for i, p in enumerate(prompts):
        want = manual_greedy(params, cfg, p, 4, 48)
        assert by_rid[i].tokens == want, (i, by_rid[i].tokens, want)
    assert eng.prefix_cache.evictions > 0
    _drained(eng)
