"""Property-based tests (hypothesis) on system invariants."""
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (fast tier) — property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core.quant import (dequantize, quantize_per_channel,
                              quantize_per_row)
from repro.core.rowwise import V5E, plan_matmul
from repro.launch import hlo_cost
from repro.optim import adamw
from repro.serve.paging import PagePool

dims = st.integers(min_value=1, max_value=4096)


@settings(max_examples=60, deadline=None)
@given(m=dims, k=dims, n=dims,
       dtype_bytes=st.sampled_from([1, 2, 4]))
def test_plan_matmul_invariants(m, k, n, dtype_bytes):
    p = plan_matmul(m, k, n, dtype_bytes=dtype_bytes)
    # tiles divide the padded problem exactly
    assert p.m_pad % p.bm == 0 and p.n_pad % p.bn == 0
    assert p.m_pad >= m and p.n_pad >= n and p.k_pad >= k
    assert p.k_splits * p.bk >= k
    # the fused adder tree needs the k axis to tile K exactly
    assert p.k_pad == p.k_splits * p.bk
    # utilization = useful / padded is a true fraction
    assert 0.0 < p.utilization <= 1.0
    # claimed working set fits VMEM
    assert p.vmem_bytes <= V5E.vmem_bytes
    # grid covers the padded output exactly, k innermost
    assert p.grid == (p.n_pad // p.bn, p.m_pad // p.bm, p.k_splits)
    # flops are exactly 2*m*k*n (no phantom work in the plan)
    assert p.flops == 2 * m * k * n


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_quantization_error_bound(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(16, 8)) * rng.uniform(0.1, 10),
                    jnp.float32)
    q, s = quantize_per_channel(w)
    err = jnp.abs(q.astype(jnp.float32) * s - w)
    # symmetric int8: error bounded by half a quantization step
    assert float(jnp.max(err - s / 2)) < 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_activation_quant_rows_independent(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    q1, s1 = quantize_per_row(x)
    # scaling one row must not change other rows' quantization
    x2 = x.at[0].multiply(100.0)
    q2, s2 = quantize_per_row(x2)
    np.testing.assert_array_equal(np.asarray(q1[1:]), np.asarray(q2[1:]))


@settings(max_examples=20, deadline=None)
@given(warmup=st.integers(1, 100), total=st.integers(200, 10_000))
def test_cosine_schedule_bounds(warmup, total):
    for step in (0, warmup, total // 2, total, total * 2):
        v = float(adamw.cosine_schedule(jnp.asarray(step, jnp.int32),
                                        warmup=warmup, total=total))
        assert 0.0 <= v <= 1.0 + 1e-6
    assert float(adamw.cosine_schedule(
        jnp.asarray(warmup, jnp.int32), warmup=warmup, total=total)) > 0.9


@settings(max_examples=10, deadline=None)
@given(trips=st.integers(2, 40))
def test_hlo_cost_scales_with_trip_count(trips):
    """The while-trip scaling that cost_analysis lacks."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=trips)
        return out

    x = jnp.ones((8, 16))
    w = jnp.ones((16, 16))
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    cost = hlo_cost.analyze_hlo(hlo)
    expect = 2 * 8 * 16 * 16 * trips
    assert abs(cost.flops - expect) / expect < 0.05


@settings(max_examples=50, deadline=None)
@given(st.data())
def test_page_pool_invariants(data):
    """Random admit / extend / retire / transaction traffic against the
    serving page allocator, driven exactly the way the engine drives it
    (reservation check, FIFO head-only admission, lazy ensure within the
    reservation, begin/commit/rollback brackets around mutations,
    rollback_tail for speculative tail returns). Invariants after every
    operation:

      * conservation — free pages + live pages == total real pages;
      * no page is ever granted twice (live table entries are distinct,
        disjoint from the free list, and never a scratch page);
      * deferral is FIFO — requests are admitted in submission order,
        and a rolled-back admission replays without reordering;
      * a retired slot's table points back at its OWN scratch page;
      * ``rollback`` restores the exact pre-``begin`` allocator state
        while still bumping ``version`` (shipped-table staleness).
    """
    n_slots = data.draw(st.integers(1, 4), label="n_slots")
    page_size = data.draw(st.sampled_from([4, 8, 16]), label="page_size")
    max_pages = data.draw(st.integers(1, 6), label="max_pages")
    n_pages = data.draw(st.integers(1, n_slots * max_pages),
                        label="n_pages")
    pool = PagePool(n_pages, page_size, n_slots, max_pages)
    max_len = max_pages * page_size
    # scratch pages are per-slot, distinct, and outside the real range
    assert sorted(pool.scratch) == list(range(n_pages, n_pages + n_slots))

    queue: deque = deque()
    live: dict = {}                       # slot -> (rid, reserved_tokens)
    next_rid = 0
    admitted = []
    # model snapshots parallel to the pool's transaction stack: a
    # rollback must revert the *driver's* view (queue, live set,
    # admission log, rid counter) together with the allocator, exactly
    # like the engine re-queues work whose admission rolled back
    model_stack = []
    ops = data.draw(st.lists(
        st.sampled_from(["submit", "admit", "extend", "retire",
                         "begin", "commit", "rollback", "rollback_tail"]),
        min_size=1, max_size=60), label="ops")
    for op in ops:
        if op == "submit":
            queue.append((next_rid, data.draw(st.integers(1, max_len))))
            next_rid += 1
        elif op == "admit":
            free_slots = [s for s in range(n_slots) if s not in live]
            if queue and free_slots:
                rid, ln = queue[0]        # head only: FIFO, never skip
                if pool.can_admit(ln):
                    queue.popleft()
                    slot = free_slots[0]
                    pool.admit(slot, ln)
                    pool.ensure(slot, data.draw(st.integers(1, ln)))
                    live[slot] = (rid, ln)
                    admitted.append(rid)
        elif op == "extend" and live:
            slot = data.draw(st.sampled_from(sorted(live)))
            pool.ensure(slot, data.draw(st.integers(1, live[slot][1])))
        elif op == "retire" and live:
            slot = data.draw(st.sampled_from(sorted(live)))
            pool.release(slot)
            del live[slot]
            assert (pool.tables[slot] == pool.scratch[slot]).all()
        elif op == "begin":
            pool.begin()
            model_stack.append((deque(queue), dict(live), list(admitted),
                                next_rid, list(pool.free),
                                pool.tables.copy(), pool.n_alloc.copy(),
                                pool.reserved.copy()))
        elif op == "commit" and model_stack:
            pool.commit()
            model_stack.pop()
        elif op == "rollback" and model_stack:
            v0 = pool.version
            pool.rollback()
            (queue, live, admitted, next_rid,
             free0, tables0, n_alloc0, reserved0) = model_stack.pop()
            # exact state restoration, monotonic version
            assert pool.free == free0
            assert (pool.tables == tables0).all()
            assert (pool.n_alloc == n_alloc0).all()
            assert (pool.reserved == reserved0).all()
            assert pool.version > v0
        elif op == "rollback_tail" and live:
            slot = data.draw(st.sampled_from(sorted(live)))
            keep = data.draw(st.integers(0, live[slot][1]),
                             label="keep_tokens")
            before = int(pool.n_alloc[slot])
            freed = pool.rollback_tail(slot, keep)
            assert freed == before - int(pool.n_alloc[slot]) >= 0
            # the reservation survives a tail rollback (the worst case
            # of the sequence is unchanged by dropping its tail)
            assert pool.reserved[slot] == pool._pages_for(live[slot][1])
        # conservation + no double allocation, after every op
        assert len(pool.free) + pool.live_pages() == n_pages
        granted = [int(p) for s in range(n_slots)
                   for p in pool.tables[s, :pool.n_alloc[s]]]
        assert len(granted) == len(set(granted))
        assert set(granted).isdisjoint(pool.free)
        assert all(p < n_pages for p in granted)
    # unwind any still-open transactions: keep their mutations
    while pool.in_transaction():
        pool.commit()
        model_stack.pop()
    assert len(pool.free) + pool.live_pages() == n_pages
    # FIFO: the admitted requests are exactly the first ones submitted,
    # in order — deferral (and rollback replay) never reorders past the
    # queue head
    assert admitted == list(range(len(admitted)))


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_rollback_tail_page_boundaries(data):
    """The speculative-step contract on ``rollback_tail`` (PR 10): a
    verify step draws pages for ``host_len + 1 + draft_len`` tokens,
    accepts some prefix ``n_acc ∈ {0..draft_len}``, and rolls the rest
    back. For every acceptance count — page-exact fills included (the
    off-by-one regime: ``keep`` landing exactly on a page boundary) —
    the rollback must never free a page holding accepted tokens, never
    leak a page holding only rejected ones, keep the accepted page
    prefix bit-identical, and leave the reservation untouched so the
    slot's worst case still fits."""
    page_size = data.draw(st.sampled_from([4, 8]), label="page_size")
    max_pages = data.draw(st.integers(2, 6), label="max_pages")
    max_len = page_size * max_pages
    pool = PagePool(max_pages, page_size, 1, max_pages)
    pool.admit(0, max_len)
    host_len = data.draw(st.integers(1, max_len - 2), label="host_len")
    if data.draw(st.booleans(), label="snap_host_to_page"):
        # exercise the boundary: committed tokens exactly fill pages
        host_len = max(page_size, (host_len // page_size) * page_size)
    draft_len = data.draw(
        st.integers(0, min(8, max_len - host_len - 2)), label="draft_len")
    pool.ensure(0, host_len)
    committed = [int(p) for p in pool.tables[0, :pool.n_alloc[0]]]
    pool.ensure(0, host_len + 1 + draft_len)    # the spec step's draws
    drawn = [int(p) for p in pool.tables[0, :pool.n_alloc[0]]]
    assert drawn[:len(committed)] == committed

    n_acc = data.draw(st.integers(0, draft_len), label="n_acc")
    keep = host_len + 1 + n_acc
    n_keep_pages = pool._pages_for(keep)
    accepted_pages = drawn[:n_keep_pages]
    freed = pool.rollback_tail(0, keep)

    # never leak a rejected-only page: allocation shrinks to exactly
    # the accepted footprint, and every freed page is back on the list
    assert int(pool.n_alloc[0]) == n_keep_pages
    assert freed == len(drawn) - n_keep_pages
    assert set(drawn[n_keep_pages:]) <= set(pool.free)
    # never free an accepted page: the kept prefix is bit-identical
    # and disjoint from the free list
    assert [int(p) for p in pool.tables[0, :n_keep_pages]] \
        == accepted_pages
    assert set(accepted_pages).isdisjoint(pool.free)
    # the reservation survives — the sequence's worst case is unchanged
    assert int(pool.reserved[0]) == pool._pages_for(max_len)
    pool.check_conservation()
    # a second, deeper rollback (retire-style) composes cleanly
    freed2 = pool.rollback_tail(0, host_len)
    assert int(pool.n_alloc[0]) == pool._pages_for(host_len)
    assert freed2 == n_keep_pages - pool._pages_for(host_len)
    pool.check_conservation()


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_page_pool_refcount_invariants(data):
    """Random shared-page traffic (PR 8) against the refcounted pool:
    admissions, prefix-style ``map_shared`` grafts (with and without a
    COW-pending tail), COW resolutions, tree-style adopt/drop
    references, releases, and transaction brackets. Invariants after
    every operation (via ``check_conservation`` plus local asserts):

      * refcount conservation — free + referenced == total pages, a
        page's table multiplicity never exceeds its refcount, and no
        free page keeps a reference;
      * COW on a sole-referenced page claims it in place — no draw,
        no free-list change; COW on a shared page draws exactly one
        private page and leaves both sides at the right counts;
      * ``deref`` frees a page exactly when the last reference goes —
        an extant reference (tree or table) always pins it;
      * rollback restores refcounts and COW-pending marks exactly.
    """
    n_slots = data.draw(st.integers(2, 4), label="n_slots")
    page_size = 4
    max_pages = data.draw(st.integers(2, 5), label="max_pages")
    n_pages = data.draw(st.integers(2, n_slots * max_pages),
                        label="n_pages")
    pool = PagePool(n_pages, page_size, n_slots, max_pages)
    live: set = set()
    adopted: list = []                    # "tree" references we hold
    stack = []                            # model snapshots per begin()
    ops = data.draw(st.lists(
        st.sampled_from(["admit", "share", "cow", "adopt", "drop",
                         "release", "begin", "commit", "rollback"]),
        min_size=1, max_size=60), label="ops")
    for op in ops:
        if op == "admit":
            free_slots = [s for s in range(n_slots) if s not in live]
            if free_slots:
                ln = data.draw(st.integers(1, max_pages * page_size))
                if pool.can_admit(ln):
                    slot = free_slots[0]
                    pool.admit(slot, ln)
                    pool.ensure(slot, data.draw(st.integers(1, ln)))
                    live.add(slot)
        elif op == "share":
            # graft a donor's leading pages into a fresh slot, engine
            # style: reserve first, then map; optionally COW-pending
            free_slots = [s for s in range(n_slots) if s not in live]
            donors = [s for s in live if pool.n_alloc[s] >= 1]
            if free_slots and donors:
                donor = data.draw(st.sampled_from(sorted(donors)))
                k = data.draw(st.integers(1, int(pool.n_alloc[donor])))
                if pool.can_admit_pages(k):
                    slot = free_slots[0]
                    pages = [int(p) for p in pool.tables[donor, :k]]
                    before = pool.refs[pages].copy()
                    pool.admit(slot, k * page_size)
                    cow = data.draw(st.booleans(), label="cow_tail")
                    pool.map_shared(slot, pages[:-1])
                    pool.map_shared(slot, pages[-1:], cow_tail=cow)
                    live.add(slot)
                    assert (pool.refs[pages] == before + 1).all()
                    assert pool.cow_idx[slot] == (k - 1 if cow else -1)
        elif op == "cow":
            slots = [s for s in live if pool.cow_idx[s] >= 0]
            if slots:
                slot = data.draw(st.sampled_from(sorted(slots)))
                logical = int(pool.cow_idx[slot])
                page = int(pool.tables[slot, logical])
                shared = pool.refs[page] > 1
                if shared and not pool.free:
                    continue              # engine's _make_room ran out
                free0 = len(pool.free)
                src, dst = pool.cow(slot, logical)
                assert src == page and pool.cow_idx[slot] == -1
                if shared:
                    # private copy: one draw, both sides refcount 1 side
                    assert dst != src and len(pool.free) == free0 - 1
                    assert pool.refs[dst] == 1
                    assert int(pool.tables[slot, logical]) == dst
                else:
                    # sole reference: claimed in place, no draw
                    assert dst == src and len(pool.free) == free0
        elif op == "adopt":
            granted = [int(p) for s in live
                       for p in pool.tables[s, :pool.n_alloc[s]]]
            if granted:
                page = data.draw(st.sampled_from(sorted(set(granted))))
                pool.ref_page(page)
                adopted.append(page)
        elif op == "drop" and adopted:
            page = adopted.pop(data.draw(
                st.integers(0, len(adopted) - 1)))
            was = int(pool.refs[page])
            freed = pool.deref(page)
            # freed exactly when the last reference went
            assert freed == (was == 1)
            assert (page in pool.free) == freed
        elif op == "release" and live:
            slot = data.draw(st.sampled_from(sorted(live)))
            pool.release(slot)
            live.discard(slot)
            assert pool.cow_idx[slot] == -1
        elif op == "begin":
            pool.begin()
            stack.append((set(live), list(adopted), pool.refs.copy(),
                          pool.cow_idx.copy()))
        elif op == "commit" and stack:
            pool.commit()
            stack.pop()
        elif op == "rollback" and stack:
            pool.rollback()
            live, adopted, refs0, cow0 = stack.pop()
            live, adopted = set(live), list(adopted)
            assert (pool.refs == refs0).all()
            assert (pool.cow_idx == cow0).all()
        pool.check_conservation()
        # an extant reference always pins its page off the free list
        for page in adopted:
            assert pool.refs[page] >= 1 and page not in pool.free
    while pool.in_transaction():
        pool.commit()
    for slot in sorted(live):
        pool.release(slot)
    while adopted:
        pool.deref(adopted.pop())
    pool.check_conservation()
    assert sorted(pool.free) == list(range(n_pages))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_grad_clip_norm_bound(seed):
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.normal(size=(32,)) * 100, jnp.float32)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(adamw.global_norm(clipped)) <= 1.0 + 1e-5
