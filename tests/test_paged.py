"""Paged-KV serving: allocator, block-table attention, engine parity.

The fast tier covers the host-side allocator/buckets, the page-gather
attention primitive against the dense chunked oracle, and the modeled
KV-traffic acceptance criterion. The slow tier drives the full engine:
paged continuous batching must reproduce dense-cache greedy decoding
token for token across mixed prompt lengths, sliding-window layers and
slot reuse, while compiling at most ``n_buckets + 1`` programs
(``n_buckets + n_chunk_shapes + 1`` once chunked prefill is on —
chunked-path parity itself lives in ``test_chunked_prefill.py``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import manual_greedy

from repro.analysis import compile_bound
from repro.configs import REDUCED
from repro.core.block_traffic import (dense_kv_step_bytes, kv_layer_counts,
                                      paged_kv_step_bytes,
                                      serve_kv_traffic)
from repro.core.types import PagingConfig
from repro.models import attention, lm
from repro.serve import sampling
from repro.serve.engine import Engine, Request
from repro.serve.paging import (PagePool, bucket_for, default_buckets,
                                page_aligned_size, supports_bucketing)


# ----------------------------------------------------------------------
# Host-side bookkeeping (fast)
# ----------------------------------------------------------------------


def test_page_pool_alloc_release_reuse():
    pool = PagePool(n_pages=8, page_size=4, n_slots=2, max_pages=4)
    # idle tables point at each slot's PRIVATE scratch page (8, 9) —
    # never at one shared page
    assert list(pool.scratch) == [8, 9]
    assert (pool.tables[0] == 8).all() and (pool.tables[1] == 9).all()
    assert pool.can_admit(16)            # 4 pages of 4 tokens
    pool.admit(0, 16)
    pool.ensure(0, 9)                    # 3 pages
    assert pool.n_alloc[0] == 3 and pool.live_pages() == 3
    assert sorted(pool.tables[0, :3]) == sorted(set(pool.tables[0, :3]))
    # reservations count against admission even before pages are drawn
    assert pool.can_admit(16)            # 8 - 3 live - 1 outstanding >= 4
    assert not pool.can_admit(20)        # 5 pages won't fit
    pool.admit(1, 16)
    pool.ensure(1, 16)
    assert len(pool.free) == 1
    granted = set(pool.tables[0, :3]) | set(pool.tables[1, :4])
    assert len(granted) == 7             # no page granted twice
    pool.release(0)
    assert (pool.tables[0] == pool.scratch[0]).all()
    assert pool.live_pages() == 4 and len(pool.free) == 4
    pool.admit(0, 16)
    pool.ensure(0, 16)                   # reuses the freed pages
    assert pool.live_pages() == 8


def test_bucket_policy():
    assert default_buckets(128) == [16, 32, 64, 128]
    assert default_buckets(48) == [16, 32, 48]
    assert bucket_for(5, [16, 32]) == 16
    assert bucket_for(16, [16, 32]) == 16
    assert bucket_for(17, [16, 32]) == 32
    with pytest.raises(ValueError):
        bucket_for(33, [16, 32])
    assert supports_bucketing(REDUCED["deepseek-7b"]())
    assert supports_bucketing(REDUCED["gemma3-27b"]())
    assert not supports_bucketing(REDUCED["rwkv6-3b"]())      # recurrent
    assert not supports_bucketing(REDUCED["qwen2-moe-a2.7b"]())  # MoE
    # ring pages must tile the window: gemma3 smoke window=16
    assert page_aligned_size(16, REDUCED["gemma3-27b"]()) == 16
    assert page_aligned_size(24, REDUCED["gemma3-27b"]()) == 8


def test_engine_rejects_bad_bucket_overrides():
    """Caller-supplied buckets must cover max_len (else admission would
    fail mid-run after mutating the pool) and are refused outright for
    archs whose prefill state makes padding inexact."""
    key = jax.random.PRNGKey(0)
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    with pytest.raises(ValueError):
        Engine(params, cfg, n_slots=2, max_len=64, buckets=[16])
    eng = Engine(params, cfg, n_slots=2, max_len=64, buckets=[32, 64])
    assert eng.buckets == [32, 64]
    rcfg = REDUCED["rwkv6-3b"]()
    rparams, _ = lm.init_lm(key, rcfg, dtype=jnp.float32)
    with pytest.raises(ValueError):
        Engine(rparams, rcfg, n_slots=2, max_len=64, buckets=[16, 64])


# ----------------------------------------------------------------------
# Page-gather attention vs the dense chunked oracle (fast)
# ----------------------------------------------------------------------


def _build_pool(k, v, page_size, rng):
    """Scatter dense (B,S,Hkv,hd) states into a shuffled page pool."""
    b, s, hkv, hd = k.shape
    npp = s // page_size
    n_pages = b * npp
    perm = rng.permutation(n_pages)
    tables = perm.reshape(b, npp).astype(np.int32)
    pool_k = np.zeros((n_pages + 1, page_size, hkv, hd), np.float32)
    pool_v = np.zeros((n_pages + 1, page_size, hkv, hd), np.float32)
    for bi in range(b):
        for p in range(npp):
            sl = slice(p * page_size, (p + 1) * page_size)
            pool_k[tables[bi, p]] = np.asarray(k[bi, sl])
            pool_v[tables[bi, p]] = np.asarray(v[bi, sl])
    return jnp.asarray(pool_k), jnp.asarray(pool_v), jnp.asarray(tables)


@pytest.mark.parametrize("chunk", [1024, 8])
def test_paged_attention_matches_chunked(chunk):
    key = jax.random.PRNGKey(0)
    b, hq, hkv, hd, ps = 3, 4, 2, 8, 4
    s = 32
    q = jax.random.normal(key, (b, hq, 1, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, hd))
    lengths = jnp.asarray([5, 32, 11])
    ref = attention.chunked_attention(
        q, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=False, window=0, kv_len=lengths)
    pool_k, pool_v, tables = _build_pool(k, v, ps,
                                         np.random.default_rng(0))
    out = attention.chunked_attention(q, pool_k, pool_v, causal=False,
                                      window=0, kv_len=lengths,
                                      pages=tables, chunk=chunk)
    if chunk >= s:       # one online-softmax step each: bit-identical
        assert bool(jnp.all(out == ref))
    else:                # different chunking: same math, ulp-level
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_write_pages_appends_to_tail_page():
    b, hkv, hd, ps = 2, 2, 4, 4
    pool = attention.PagedKVCache(k=jnp.zeros((5, ps, hkv, hd)),
                                  v=jnp.zeros((5, ps, hkv, hd)))
    tables = jnp.asarray([[2, 0], [3, 1]], jnp.int32)
    k_new = jnp.ones((b, 1, hkv, hd))
    v_new = 2 * jnp.ones((b, 1, hkv, hd))
    # slot 0 at position 5 => logical page 1 (physical 0), offset 1;
    # slot 1 at position 2 => logical page 0 (physical 3), offset 2
    pool = attention.write_pages(pool, k_new, v_new,
                                 jnp.asarray([5, 2]), tables)
    assert bool(jnp.all(pool.k[0, 1] == 1.0))
    assert bool(jnp.all(pool.v[3, 2] == 2.0))
    assert float(jnp.abs(pool.k).sum()) == hkv * hd * b   # nothing else


def test_idle_slot_writes_do_not_alias_one_page():
    """DESIGN.md §4 follow-up (2) regression: idle slots write their own
    scratch page, not one shared trash page — the lockstep writes land
    in disjoint storage (XLA can overlap or drop them instead of
    serializing), and no idle slot can observe another's garbage row."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    eng = Engine(params, cfg, n_slots=3, max_len=32, eos_id=-1)
    idle_rows = {tuple(set(eng.pool.tables[s])) for s in range(3)}
    assert len(idle_rows) == 3            # pairwise distinct scratch ids
    # device-level: two idle slots' lockstep writes land on their own
    # scratch pages and nothing aliases
    hkv, hd, ps = 2, 4, 4
    pool = attention.PagedKVCache(k=jnp.zeros((4, ps, hkv, hd)),
                                  v=jnp.zeros((4, ps, hkv, hd)))
    tables = jnp.asarray([[2, 2], [3, 3]], jnp.int32)   # scratch = 2, 3
    k_new = jnp.stack([jnp.full((1, hkv, hd), 1.0),
                       jnp.full((1, hkv, hd), 5.0)])
    pool = attention.write_pages(pool, k_new, k_new,
                                 jnp.asarray([0, 0]), tables)
    assert bool(jnp.all(pool.k[2, 0] == 1.0))
    assert bool(jnp.all(pool.k[3, 0] == 5.0))
    assert float(jnp.abs(pool.k[:2]).sum()) == 0.0      # real pages clean


def test_write_pages_ring_wraps_window():
    hkv, hd, ps = 1, 2, 4
    pool = attention.PagedKVCache(k=jnp.zeros((4, ps, hkv, hd)),
                                  v=jnp.zeros((4, ps, hkv, hd)))
    tables = jnp.asarray([[1, 2, 0]], jnp.int32)   # ring = first 2 pages
    # window=8: position 9 wraps to ring index 1 => page 0 (phys 1) off 1
    pool = attention.write_pages(pool, jnp.ones((1, 1, hkv, hd)),
                                 jnp.ones((1, 1, hkv, hd)),
                                 jnp.asarray([9]), tables, window=8)
    assert bool(jnp.all(pool.k[1, 1] == 1.0))


# ----------------------------------------------------------------------
# Traffic model acceptance (fast)
# ----------------------------------------------------------------------


def test_paged_traffic_beats_dense_2x():
    """ISSUE acceptance: on a trace whose mean live length is at most
    max_len / 4, paged decode models >= 2x fewer KV HBM bytes than the
    dense n_slots x max_len lockstep caches."""
    cfg = REDUCED["deepseek-7b"]()
    n_slots, max_len, ps = 4, 128, 16
    lens = [5, 17, 32, 21]                       # prompt lengths
    assert np.mean(lens) <= max_len / 4
    trace = [[ln + t for ln in lens] for t in range(16)]
    out = serve_kv_traffic(trace, cfg, n_slots=n_slots, max_len=max_len,
                           page_size=ps)
    assert out["ratio"] >= 2.0, out
    assert out["paged_bytes"] * 2 <= out["dense_bytes"]


def test_traffic_model_shapes():
    cfg = REDUCED["gemma3-27b"]()                # 2 local : 1 global mix
    n_global, n_local, window = kv_layer_counts(cfg)
    assert n_global > 0 and n_local > 0 and window == 16
    row = 2 * cfg.n_kv_heads * cfg.head_dim * 2
    dense = dense_kv_step_bytes(n_slots=2, max_len=64, n_global=n_global,
                                n_local=n_local, window=window,
                                n_kv_heads=cfg.n_kv_heads,
                                head_dim=cfg.head_dim)
    # windowed layers cap at window, global layers pay max_len
    assert dense == row * 2 * (n_global * 64 + n_local * 16)
    paged = paged_kv_step_bytes([10], page_size=8, n_global=n_global,
                                n_local=n_local, window=window,
                                n_kv_heads=cfg.n_kv_heads,
                                head_dim=cfg.head_dim)
    # 10 live tokens round to 16 (two pages); ring also 16
    assert paged == row * (n_global * 16 + n_local * 16)
    # idle slots cost nothing in the paged model
    assert paged_kv_step_bytes([], page_size=8, n_global=n_global,
                               n_kv_heads=cfg.n_kv_heads,
                               head_dim=cfg.head_dim) == 0


def test_per_row_temperature_sampling():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0],
                          [9.0, 0.0, 0.0, 0.0]])
    # scalar zero (and any non-positive scalar) short-circuits to greedy
    assert sampling.sample(logits, key, temperature=0.0).tolist() == [1, 0]
    assert sampling.sample(logits, key, temperature=-1.0).tolist() == [1, 0]
    # per-row: row 0 greedy, row 1 sampled (valid token either way)
    t = jnp.asarray([0.0, 1.0])
    out = sampling.sample(logits, key, temperature=t)
    assert int(out[0]) == 1
    assert 0 <= int(out[1]) < 4
    # all-greedy rows match the scalar fast path exactly
    out0 = sampling.sample(logits, key, temperature=jnp.zeros(2))
    assert out0.tolist() == [1, 0]
    # 0-d numpy / jnp scalars keep working like python floats
    assert sampling.sample(logits, key,
                           temperature=np.float32(0.0)).tolist() == [1, 0]
    assert 0 <= int(sampling.sample(logits, key,
                                    temperature=jnp.float32(0.8))[0]) < 4


# ----------------------------------------------------------------------
# Engine: paged vs dense greedy parity + compile stability (slow)
# ----------------------------------------------------------------------


@pytest.mark.slow
def test_paged_matches_dense_mixed_lengths_and_slot_reuse():
    """Greedy token streams of the paged engine equal dense-cache decode
    exactly, across mixed prompt lengths with more requests than slots
    (so retired slots hand pages back and are refilled)."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    plens = [3, 9, 17, 6, 12]
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (p,), 0,
                                  cfg.vocab) for i, p in enumerate(plens)]
    n_new = 6
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                 paging=PagingConfig(page_size=8))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=n_new))
    done = eng.run()
    assert sorted(c.rid for c in done) == list(range(len(prompts)))
    by_rid = {c.rid: c for c in done}
    for i, p in enumerate(prompts):
        want = manual_greedy(params, cfg, p, n_new, 32)
        assert by_rid[i].tokens == want, (i, by_rid[i].tokens, want)


@pytest.mark.slow
def test_paged_matches_dense_sliding_window():
    """Ring-buffer pages: a gemma-style local/global mix decoding well
    past the window reproduces dense ring-cache decode exactly."""
    cfg = REDUCED["gemma3-27b"]()                # window=16
    key = jax.random.PRNGKey(1)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    plens = [20, 5, 11]                          # one prompt > window
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (p,), 0,
                                  cfg.vocab) for i, p in enumerate(plens)]
    n_new = 12                                   # 20 + 12 decodes past 16
    eng = Engine(params, cfg, n_slots=2, max_len=48, eos_id=-1,
                 paging=PagingConfig(page_size=8))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=n_new))
    done = eng.run()
    by_rid = {c.rid: c for c in done}
    for i, p in enumerate(prompts):
        want = manual_greedy(params, cfg, p, n_new, 48)
        assert by_rid[i].tokens == want, (i, by_rid[i].tokens, want)


@pytest.mark.slow
def test_engine_compile_stability():
    """Continuous batching over mixed prompt lengths compiles at most
    n_buckets prefill programs + 1 decode program."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(2)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    eng = Engine(params, cfg, n_slots=2, max_len=64, eos_id=-1)
    assert eng.buckets == [16, 32, 64]
    # 8 distinct prompt lengths spanning every bucket
    for i, plen in enumerate([3, 5, 9, 17, 21, 33, 40, 13]):
        eng.submit(Request(rid=i, prompt=jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 0, cfg.vocab),
            max_new=4))
    eng.run()
    counts = eng.compile_counts()
    assert 0 < counts["prefill"] <= len(eng.buckets)
    assert counts["step"] == 1
    assert counts["prefill"] + counts["step"] <= len(eng.buckets) + 1
    # host-side proxy (distinct padded lengths) agrees with the jit cache
    assert counts["prefill"] == len(eng._prefill_lens)
    # the auditor's static enumeration predicts the jit caches EXACTLY:
    # any drift means a shape source the closed-form bound doesn't model
    expected = compile_bound.predict_compile_counts(
        [3, 5, 9, 17, 21, 33, 40, 13], max_len=64)
    assert counts == expected
    assert compile_bound.check_engine_counts(eng, expected).ok


@pytest.mark.slow
def test_compile_stability_mixed_chunked_traffic():
    """The PR 3 bound extended to chunked prefill: mixed chunked /
    unchunked traffic compiles at most n_buckets one-shot prefill
    programs + n_chunk_shapes chunk programs + 1 decode program, with
    the jit caches cross-checked against the host-side program
    counters."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(6)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    eng = Engine(params, cfg, n_slots=2, max_len=64, eos_id=-1,
                 paging=PagingConfig(prefill_chunk=16))
    assert eng.buckets == [16, 32, 64]
    # spans: unchunked (<= chunk), chunk-divisible, non-divisible,
    # plen == max_len, and repeats that must all hit compiled programs
    for i, plen in enumerate([3, 16, 17, 21, 32, 40, 64, 5, 50, 33]):
        eng.submit(Request(rid=i, prompt=jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 0, cfg.vocab),
            max_new=4))
    eng.run()
    counts = eng.compile_counts()
    n_chunk_shapes = len([b for b in eng.buckets
                          if b <= eng.prefill_chunk])
    assert 0 < counts["prefill"] <= len(eng.buckets)
    assert 0 < counts["chunk"] <= n_chunk_shapes
    assert counts["step"] == 1
    assert (counts["prefill"] + counts["chunk"] + counts["step"]
            <= len(eng.buckets) + n_chunk_shapes + 1)
    # host-side program counters agree with the jit caches
    assert counts["prefill"] == len(eng._prefill_lens)
    assert counts["chunk"] == len(eng._chunk_shapes)
    # every chunk shape sits on the bucket ladder at or below the chunk
    assert all(s in eng.buckets and s <= eng.prefill_chunk
               for s in eng._chunk_shapes)
    # static enumeration == runtime jit caches, exactly
    expected = compile_bound.predict_compile_counts(
        [3, 16, 17, 21, 32, 40, 64, 5, 50, 33], max_len=64,
        prefill_chunk=16)
    assert counts == expected
    assert compile_bound.check_engine_counts(eng, expected).ok
    inv = compile_bound.enumerate_programs(
        max_len=64, page_size=eng.page_size, prefill_chunk=16)
    assert set(eng._prefill_lens) <= set(inv.prefill_lens)
    assert set(eng._chunk_shapes) <= set(inv.chunk_shapes)
    assert set(eng._step_widths) <= set(inv.step_widths)


@pytest.mark.slow
def test_table_width_bucketing_parity_and_compile_ladder():
    """With ``table_width_bucketing`` on, the decode step sees block
    tables sliced to the pow2-rounded max live page count instead of
    always ``max_pages``. Streams stay bit-identical to the full-width
    engine and the decode-step compile count is bounded by the width
    ladder (one program per pow2 width <= max_pages) instead of 1."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(9)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    n_new = 4
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (plen,), 0,
                                  cfg.vocab)
               for i, plen in enumerate([3, 9, 17, 26, 5])]

    def run(twb):
        eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                     paging=PagingConfig(page_size=4,
                                         table_width_bucketing=twb))
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new=n_new))
        done = {c.rid: c.tokens for c in eng.run()}
        return eng, done

    # the full-width engine is the oracle: its own dense-greedy parity
    # is already pinned by the mixed-lengths test above
    wide_eng, wide = run(False)
    narrow_eng, narrow = run(True)
    assert narrow == wide                       # bit-identical streams
    # full-width engine keeps the PR 3 single-program guarantee...
    assert wide_eng.compile_counts()["step"] == 1
    # ...while the bucketed engine compiles one decode program per
    # pow2 width actually used, bounded by the log2 ladder
    ladder = int(np.log2(narrow_eng.max_pages)) + 1
    steps = narrow_eng.compile_counts()["step"]
    assert 0 < steps <= ladder
    assert steps == len(narrow_eng._step_widths)
    # short-prompt traffic really did use a narrower table
    assert min(narrow_eng._step_widths) < narrow_eng.max_pages
    assert all(w & (w - 1) == 0 for w in narrow_eng._step_widths)


@pytest.mark.slow
def test_oversubscribed_pool_defers_and_completes():
    """A pool smaller than full occupancy defers admission until pages
    free up, and every request still decodes the dense-greedy stream."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(3)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    # 2 slots x 4 max_pages = 8 pages for full occupancy; give 5
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1,
                 paging=PagingConfig(page_size=8, n_pages=5))
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (7,), 0,
                                  cfg.vocab) for i in range(3)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=4))
    done = eng.run()
    assert sorted(c.rid for c in done) == [0, 1, 2]
    for i, p in enumerate(prompts):
        want = manual_greedy(params, cfg, p, 4, 32)
        assert next(c for c in done if c.rid == i).tokens == want
    assert eng.pool.live_pages() == 0            # everything reclaimed
    assert len(eng.pool.free) == 5


@pytest.mark.slow
def test_max_new_one_and_submit_validation():
    """max_new=1 completes with exactly the prefill-sampled token (no
    stray decode step), and oversized prompts are rejected at submit
    instead of wedging the run loop. A prompt of exactly max_len is
    serviceable (prefill-only: it writes exactly max_len KV rows and
    retires at admission with the prefill-sampled token)."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(5)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=9, prompt=jnp.zeros((33,), jnp.int32)))
    with pytest.raises(ValueError):
        eng.submit(Request(rid=9, prompt=jnp.zeros((0,), jnp.int32)))
    with pytest.raises(ValueError):
        eng.submit(Request(rid=9, prompt=jnp.zeros((4,), jnp.int32),
                           max_new=0))
    # plen == max_len: accepted, effective max_new clamped to 1
    full_p = jax.random.randint(jax.random.fold_in(key, 32), (32,), 0,
                                cfg.vocab)
    eng.submit(Request(rid=32, prompt=full_p, max_new=5))
    done = eng.run()
    got = next(c for c in done if c.rid == 32)
    assert got.tokens == manual_greedy(params, cfg, full_p, 1, 32)
    assert len(got.tokens) == 1
    assert eng.pool.live_pages() == 0
    eng.completed.clear()            # run() accumulates completions
    for i in range(3):               # more requests than slots
        eng.submit(Request(rid=i, prompt=jax.random.randint(
            jax.random.fold_in(key, i), (5,), 0, cfg.vocab), max_new=1))
    done = eng.run()
    assert sorted(c.rid for c in done) == [0, 1, 2]
    assert all(len(c.tokens) == 1 for c in done)
    for i in range(3):
        prompt = jax.random.randint(jax.random.fold_in(key, i), (5,), 0,
                                    cfg.vocab)
        want = manual_greedy(params, cfg, prompt, 1, 32)
        assert next(c for c in done if c.rid == i).tokens == want
    assert eng.pool.live_pages() == 0
    # prompt at max_len-1 still gets its one in-bounds decode step
    # (write at position max_len-1) before the length cap retires it
    long_p = jax.random.randint(jax.random.fold_in(key, 9), (31,), 0,
                                cfg.vocab)
    eng.submit(Request(rid=9, prompt=long_p, max_new=4))
    done = eng.run()
    got = next(c for c in done if c.rid == 9)
    assert got.tokens == manual_greedy(params, cfg, long_p, 2, 32)
    assert got.ttft_s > 0 and got.latency_s >= got.ttft_s


@pytest.mark.slow
def test_engine_kv_trace_and_ttft_recorded():
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(4)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    eng = Engine(params, cfg, n_slots=2, max_len=32, eos_id=-1)
    eng.submit(Request(rid=0, prompt=jax.random.randint(key, (6,), 0,
                                                        cfg.vocab),
                       max_new=4))
    done = eng.run()
    assert done[0].ttft_s > 0
    assert len(eng.kv_trace) == 3                # max_new - 1 decode steps
    assert eng.kv_trace[0] == [7]                # 6 prompt + 1 decoded
