"""PR 4: fused projection param layout (wqkv / wgi stored
pre-concatenated) + serving-path bugfix regressions.

Fast tier: ops-level parity of the fused panels vs the seed's split
layout (fp32/bf16, bias/no-bias, weight-only int8), the
fuse_params/unfuse_params round-trip across every arch family, the
decode-jaxpr weight-concat audit, the modeled weight-traffic
acceptance, quantizer scale pre-concatenation, and the
submit/sampling/cache-dtype bugfix regressions. Slow tier: checkpoint
migration end-to-end, a quantized-tree engine run, and the TrainState
migration through a real train step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REDUCED
from repro.core import quant
from repro.core.block_traffic import decode_weight_traffic_cfg
from repro.kernels import ops, ref
from repro.models import attention, lm
from repro.serve import sampling
from repro.serve.engine import Engine, Request


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


# ---------------------- fused vs seed layout parity --------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_norm", [False, True])
def test_project_qkv_matches_split_layout(rng, dtype, with_norm):
    """The stored wqkv panel produces exactly what the seed's split
    wq/wk/wv leaves did, in both the fused-kernel mode (norm spec) and
    the per-op baseline mode (norm=None, panel sliced per launch)."""
    cfg = REDUCED["deepseek-7b"]()
    d = cfg.d_model
    qo, kvo, _ = attention.proj_splits(cfg)
    x = _rand(rng, (2, 5, d), dtype)
    parts = [_rand(rng, (d, w), dtype) for w in (qo, kvo, kvo)]
    params = {"wqkv": jnp.concatenate(parts, axis=-1)}
    g = _rand(rng, (d,))
    norm = ops.NormSpec("rms", g) if with_norm else None
    q, k, v = attention._project_qkv(params, x, cfg, norm)
    xr = x.reshape(-1, d)
    if with_norm:
        xr = ref.layernorm_ref(xr, g, None, kind="rms")
    rtol, atol = (1e-5, 1e-5) if dtype == jnp.float32 else (2e-2, 1e-1)
    for got, w in zip((q, k, v), parts):
        want = ref.matmul_ref(xr, w).reshape(got.shape)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=rtol, atol=atol)


def test_project_qkv_weight_only_int8(rng):
    """A weight-only int8 wqkv leaf ({"q","s"}) decodes through both
    projection modes, matching the explicitly dequantized panel."""
    cfg = REDUCED["deepseek-7b"]()
    d = cfg.d_model
    x = _rand(rng, (3, 1, d))
    w = _rand(rng, (d, sum(attention.proj_splits(cfg))))
    qw, s = quant.quantize_per_channel(w)
    params = {"wqkv": {"q": qw, "s": s}}
    deq = {"wqkv": quant.resolve_weight({"q": qw, "s": s}, jnp.float32)}
    for norm in (None, ops.NormSpec("rms", _rand(rng, (d,)))):
        got = attention._project_qkv(params, x, cfg, norm)
        want = attention._project_qkv(deq, x, cfg, norm)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)


def test_gate_up_fused_leaf_matches_split(rng):
    """gate_up_proj over the stored wg|wi panel == the seed's two
    stored halves, with and without a fused bias."""
    d, f = 64, 96
    x = _rand(rng, (2, 7, d))
    wg, wi = _rand(rng, (d, f)), _rand(rng, (d, f))
    wgi = jnp.concatenate([wg, wi], axis=-1)
    for bias in (None, _rand(rng, (2 * f,))):
        got = ops.gate_up_proj(x, wgi, activation="silu", bias=bias)
        bg = None if bias is None else bias[:f]
        bi = None if bias is None else bias[f:]
        want = ref.pipeline_ref(x.reshape(-1, d), wi, bias=bi, w_gate=wg,
                                bias_gate=bg,
                                activation="silu").reshape(got.shape)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_quantize_fused_leaf_scales_preconcatenated(rng):
    """Per-output-channel quantization commutes with the layout fusion:
    quantizing the stored wq|wk|wv panel gives bit-identical int8 values
    and scales to concatenating the per-part quantizations — int8
    scales arrive pre-concatenated, no per-call scale concat."""
    d = 48
    parts = [_rand(rng, (d, w)) for w in (32, 16, 16)]
    fused = jnp.concatenate(parts, axis=-1)
    qf, sf = quant.quantize_per_channel(fused)
    qs = [quant.quantize_per_channel(p) for p in parts]
    np.testing.assert_array_equal(
        np.asarray(qf), np.concatenate([np.asarray(q) for q, _ in qs], -1))
    np.testing.assert_array_equal(
        np.asarray(sf), np.concatenate([np.asarray(s) for _, s in qs], -1))


# ------------------- migration pair: fuse / unfuse ---------------------


@pytest.mark.parametrize("arch", ["deepseek-7b", "gemma3-27b",
                                  "whisper-base", "zamba2-1.2b",
                                  "rwkv6-3b", "qwen2-moe-a2.7b"])
def test_fuse_unfuse_roundtrip_identity(arch):
    """fuse_params(unfuse_params(p)) is the identity — structure AND
    bits — across dense, windowed, cross-attention (whisper), shared
    blocks (zamba2), recurrent (rwkv: a no-op) and MoE (experts stay
    split) archs."""
    cfg = REDUCED[arch]()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    un = lm.unfuse_params(cfg, params)
    back = lm.fuse_params(cfg, un)
    assert jax.tree.structure(params) == jax.tree.structure(back)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the seed layout genuinely differs wherever the arch has attention
    has_attn = any(blk.mixer == "attn" for st in cfg.stages()
                   for blk in st.body)
    if has_attn:
        assert jax.tree.structure(un) != jax.tree.structure(params)
    # both directions are idempotent
    assert (jax.tree.structure(lm.fuse_params(cfg, params))
            == jax.tree.structure(params))
    assert (jax.tree.structure(lm.unfuse_params(cfg, un))
            == jax.tree.structure(un))


def test_fuse_params_quantized_tree():
    """Weight-only int8 trees migrate exactly: fusing the quantized
    split leaves == quantizing the fused leaves."""
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    q_fused = quant.quantize_tree(params, quant.lm_weight_predicate)
    q_split = quant.quantize_tree(lm.unfuse_params(cfg, params),
                                  quant.lm_weight_predicate)
    refused = lm.fuse_params(cfg, q_split)
    assert jax.tree.structure(q_fused) == jax.tree.structure(refused)
    for a, b in zip(jax.tree.leaves(q_fused), jax.tree.leaves(refused)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_seed_checkpoint_restores_into_fused_layout(tmp_path):
    """A checkpoint written in the seed layout keeps loading: restore
    into the unfused structure, then fuse_params — bit-identical to the
    originally fused tree."""
    from repro.checkpoint import checkpointer as ckpt
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(2), cfg, dtype=jnp.float32)
    seed_tree = lm.unfuse_params(cfg, params)   # what an old ckpt holds
    ckpt.save(str(tmp_path), 7, seed_tree)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        seed_tree)
    restored, _ = ckpt.restore(str(tmp_path), 7, like)
    migrated = lm.fuse_params(cfg, restored)
    assert jax.tree.structure(migrated) == jax.tree.structure(params)
    for a, b in zip(jax.tree.leaves(migrated), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_fuse_state_trains():
    """A seed-layout TrainState migrates whole (params + AdamW moments)
    and steps: the optimizer runs over the fused leaves."""
    from repro.train import step as train_step_lib
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(3)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    tcfg = train_step_lib.TrainConfig(microbatches=1, remat=False,
                                      total_steps=10, warmup_steps=2)
    seed_state = train_step_lib.init_state(lm.unfuse_params(cfg, params),
                                           tcfg)
    state = train_step_lib.fuse_state(seed_state, cfg)
    want = train_step_lib.init_state(params, tcfg)
    assert (jax.tree.structure(state.params)
            == jax.tree.structure(want.params))
    assert jax.tree.structure(state.opt) == jax.tree.structure(want.opt)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    step = train_step_lib.make_train_step(cfg, tcfg)
    new_state, metrics = jax.jit(step)(state, {"tokens": tokens,
                                               "labels": tokens})
    assert bool(jnp.isfinite(metrics["loss"]))
    assert (jax.tree.structure(new_state.params)
            == jax.tree.structure(want.params))


# ------------------ decode jaxpr: no weight concatenate ----------------


def test_decode_jaxpr_has_no_weight_concat():
    """Acceptance: neither the dense nor the paged decode step traces a
    weight-sized concatenate — the per-call wq|wk|wv fuse is gone from
    the serving hot path (rope's activation-sized concats stay well
    under the threshold)."""
    from repro.analysis import min_weight_bytes, weight_concat_eqns
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    lengths = jnp.full((2,), 3, jnp.int32)
    thr = min_weight_bytes(cfg)

    dense_cache = lm.init_cache(cfg, 2, 32, jnp.float32)
    dense = jax.make_jaxpr(
        lambda p, c, t, ln: lm.decode_step(p, c, t, ln, cfg))(
            params, dense_cache, tok, lengths)
    assert weight_concat_eqns(dense, thr) == []

    paged_cache = lm.init_paged_cache(cfg, 2, 32, page_size=8,
                                      dtype=jnp.float32)
    tables = jnp.zeros((2, 4), jnp.int32)
    paged = jax.make_jaxpr(
        lambda p, c, t, ln, tb: lm.decode_step(p, c, t, ln, cfg,
                                               pages=tb))(
            params, paged_cache, tok, lengths, tables)
    assert weight_concat_eqns(paged, thr) == []

    # the audit is not vacuous: a synthetic per-call concat is caught
    def percall(p, x):
        un = lm.unfuse_params(cfg, p)
        a = un["stages"][0]["stacked"]["0"]["attn"]
        w = jnp.concatenate([a["wq"][0], a["wk"][0], a["wv"][0]], -1)
        return x @ w
    j = jax.make_jaxpr(percall)(params, jnp.zeros((2, cfg.d_model)))
    assert len(weight_concat_eqns(j, thr)) == 1


# ------------------- modeled weight-traffic acceptance -----------------


def test_decode_weight_traffic_acceptance():
    """Acceptance: at M = n_slots rows, the modeled per-step weight
    bytes of an attn+MLP block drop >= 1.5x vs the per-call-concat
    pricing (full-size deepseek-7b geometry; the smoke geometry is
    lane-padding-dominated but must still improve)."""
    from repro.configs.deepseek_7b import CONFIG as full
    pre = decode_weight_traffic_cfg(full, n_slots=4, prefused=True)
    per = decode_weight_traffic_cfg(full, n_slots=4, prefused=False)
    assert per["weight_bytes"] / pre["weight_bytes"] >= 1.5, (per, pre)
    assert per["total"] / pre["total"] >= 1.5

    smoke = REDUCED["deepseek-7b"]()
    pre_s = decode_weight_traffic_cfg(smoke, n_slots=4, prefused=True)
    per_s = decode_weight_traffic_cfg(smoke, n_slots=4, prefused=False)
    assert per_s["weight_bytes"] / pre_s["weight_bytes"] > 1.3
    # the regimes differ ONLY by the per-call concat charge
    assert pre_s["weight_bytes"] < per_s["weight_bytes"]
    names = [n for n, _, _ in pre_s["ops"]]
    assert names == [n for n, _, _ in per_s["ops"]]


# ------------------------ serving bugfix regressions -------------------


def test_sampling_top_k_clamps_to_vocab():
    """top_k >= V used to raise IndexError; now it keeps every token
    and the per-row greedy fallback survives the filters."""
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0],
                          [9.0, 0.0, 0.0, 0.0]])
    for k in (4, 5, 99):
        out = sampling.sample(logits, key, temperature=0.7, top_k=k)
        assert all(0 <= int(t) < 4 for t in out)
    # top_k == V-1 still filters (the smallest logit is excluded)
    out = sampling.sample(logits, key, temperature=100.0, top_k=1)
    assert out.tolist() == [1, 0]
    # per-row greedy rows ignore the (clamped) filters entirely
    t = jnp.asarray([0.0, 0.0])
    out = sampling.sample(logits, key, temperature=t, top_k=99)
    assert out.tolist() == [1, 0]


def test_engine_cache_dtype_derivation():
    """Explicit cache_dtype wins; array trees keep deriving from the
    embed leaf; quantized trees (dict embed) fall back to cfg.dtype
    instead of crashing in jnp.result_type."""
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    assert Engine(params, cfg, n_slots=2,
                  max_len=32).cache_dtype == jnp.float32
    assert Engine(params, cfg, n_slots=2, max_len=32,
                  cache_dtype=jnp.bfloat16).cache_dtype == jnp.bfloat16
    qtree = quant.quantize_tree(params, quant.lm_weight_predicate)
    assert isinstance(qtree["embed"], dict)
    eng = Engine(qtree, cfg, n_slots=2, max_len=32)
    assert eng.cache_dtype == jnp.dtype(cfg.dtype)


def test_quantized_moe_tree_forward():
    """Regression: lm_weight_predicate also matches the (E, d, f)
    routed-expert leaves, which the MoE einsums consume directly —
    moe.apply must dequantize them (the crash was AttributeError on the
    {"q","s"} dict)."""
    cfg = REDUCED["qwen2-moe-a2.7b"]()
    key = jax.random.PRNGKey(6)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    qtree = quant.quantize_tree(params, quant.lm_weight_predicate)
    ffn = qtree["stages"][0]["stacked"]["0"]["ffn"]
    assert quant.is_quantized(ffn["wi"])         # predicate did match
    tokens = jax.random.randint(key, (1, 8), 0, cfg.vocab)
    logits, aux = lm.forward(qtree, tokens, cfg, remat=False)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.slow
def test_quantized_tree_engine_smoke():
    """A weight-only int8 tree serves end-to-end: admission, decode and
    retirement all run on the dequant-on-the-fly path, and the greedy
    stream equals decoding the explicitly dequantized tree."""
    from conftest import manual_greedy
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(4)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    qtree = quant.quantize_tree(params, quant.lm_weight_predicate)
    eng = Engine(qtree, cfg, n_slots=2, max_len=32, eos_id=-1)
    assert eng.cache_dtype == jnp.dtype(cfg.dtype)
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (4 + i,),
                                  0, cfg.vocab) for i in range(3)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=3))
    done = eng.run()
    assert sorted(c.rid for c in done) == [0, 1, 2]
    # oracle: the explicitly dequantized tree, cast to the same compute
    # dtype the quantized tree's activations run in (cfg.dtype)
    deq = jax.tree.map(
        lambda leaf: (quant.resolve_weight(leaf, jnp.dtype(cfg.dtype))
                      if quant.is_quantized(leaf) else leaf),
        qtree, is_leaf=quant.is_quantized)
    for i, p in enumerate(prompts):
        want = manual_greedy(deq, cfg, p, 3, 32)
        assert next(c for c in done if c.rid == i).tokens == want
