"""Chunked prefill: schedule policy, chunk attention/writes, engine
parity.

The fast tier covers the host-side chunk schedule, the multi-token page
write (padding, ring wrap, clobber guard), the prefix-gather + in-chunk
LSE merge against the dense causal oracle, and the modeled stall /
re-read trade. The slow tier drives the full engine: chunked admission
must reproduce the one-shot bucketed engine's greedy streams token for
token across mixed prompt lengths, chunk sizes that are smaller than /
equal to / not dividing the prompt, sliding-window ring wraps
mid-prompt, and the plen == max_len prefill-only edge.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import manual_greedy

from repro.configs import REDUCED
from repro.core.block_traffic import (chunked_prefill_traffic,
                                      chunked_prefill_traffic_cfg)
from repro.core.types import PagingConfig
from repro.models import lm
from repro.models.attention import (PagedKVCache, _chunked_fwd,
                                    _merge_partials, _paged_fwd,
                                    write_chunk_pages)
from repro.serve.engine import Engine, Request
from repro.serve.paging import chunk_schedule


# ----------------------------------------------------------------------
# Chunk schedule policy (fast)
# ----------------------------------------------------------------------


def test_chunk_schedule_shapes_stay_on_ladder():
    buckets = [16, 32, 64, 128]
    # chunk divides plen: all full chunks
    assert chunk_schedule(64, 32, buckets) == [(0, 32, 32), (32, 32, 32)]
    # chunk does not divide plen: final partial chunk pads to a bucket
    assert chunk_schedule(70, 32, buckets) == [
        (0, 32, 32), (32, 32, 32), (64, 6, 16)]
    # plen below the chunk: a single bucketed panel
    assert chunk_schedule(9, 32, buckets) == [(0, 9, 16)]
    for plen in range(1, 129):
        sched = chunk_schedule(plen, 32, buckets)
        # offsets tile the prompt exactly, in order
        assert sched[0][0] == 0
        assert all(a[0] + a[1] == b[0] for a, b in zip(sched, sched[1:]))
        assert sched[-1][0] + sched[-1][1] == plen
        # every compiled shape is a ladder entry at or below the chunk
        assert all(s in buckets and s <= 32 and c <= s
                   for _, c, s in sched)


# ----------------------------------------------------------------------
# Multi-token page writes (fast)
# ----------------------------------------------------------------------


def _empty_pool(n_pages, ps, hkv=2, hd=4):
    return PagedKVCache(k=jnp.zeros((n_pages, ps, hkv, hd)),
                        v=jnp.zeros((n_pages, ps, hkv, hd)))


def test_write_chunk_pages_positions_and_padding():
    ps, hkv, hd = 4, 2, 4
    pool = _empty_pool(5, ps, hkv, hd)
    tables = jnp.asarray([[2, 0]], jnp.int32)
    sc = 4
    k_new = (jnp.arange(1, sc + 1, dtype=jnp.float32)[None, :, None, None]
             * jnp.ones((1, sc, hkv, hd)))
    # offset 5, chunk_len 3: positions 5,6,7 -> logical page 1 (phys 0)
    # offsets 1,2,3; the padded row 3 (would-be position 8) is dropped
    pool = write_chunk_pages(pool, k_new, 2 * k_new, jnp.int32(5),
                             jnp.int32(3), tables)
    assert bool(jnp.all(pool.k[0, 1] == 1.0))
    assert bool(jnp.all(pool.k[0, 2] == 2.0))
    assert bool(jnp.all(pool.k[0, 3] == 3.0))
    assert bool(jnp.all(pool.v[0, 1] == 2.0))
    # nothing else written anywhere (padding dropped, page 2 untouched)
    assert float(jnp.abs(pool.k).sum()) == (1 + 2 + 3) * hkv * hd
    assert float(jnp.abs(pool.k[0, 0]).sum()) == 0.0


def test_write_chunk_pages_ring_wraps_window():
    ps, hkv, hd = 4, 1, 2
    pool = _empty_pool(4, ps, hkv, hd)
    tables = jnp.asarray([[1, 2, 0]], jnp.int32)  # ring = first 2 pages
    sc = 4
    k_new = (jnp.arange(1, sc + 1, dtype=jnp.float32)[None, :, None, None]
             * jnp.ones((1, sc, hkv, hd)))
    # window=8: positions 6..9 -> ring idx 6,7,0,1 -> (phys 2, off 2/3)
    # and wrap to (phys 1, off 0/1)
    pool = write_chunk_pages(pool, k_new, k_new, jnp.int32(6),
                             jnp.int32(4), tables, window=8)
    assert bool(jnp.all(pool.k[2, 2] == 1.0))
    assert bool(jnp.all(pool.k[2, 3] == 2.0))
    assert bool(jnp.all(pool.k[1, 0] == 3.0))
    assert bool(jnp.all(pool.k[1, 1] == 4.0))


def test_write_chunk_pages_keeps_only_last_window_of_chunk():
    """A chunk longer than the window writes only its last ``window``
    positions — the earlier rows would be clobbered at the same ring
    slots anyway and no later query needs them; dropping them keeps the
    scatter's target indices duplicate-free (defined semantics)."""
    ps, hkv, hd = 2, 1, 2
    pool = _empty_pool(3, ps, hkv, hd)
    tables = jnp.asarray([[1, 0]], jnp.int32)     # ring = 2 pages (w=4)
    sc = 6
    k_new = (jnp.arange(1, sc + 1, dtype=jnp.float32)[None, :, None, None]
             * jnp.ones((1, sc, hkv, hd)))
    # window=4, positions 0..5: keep 2..5 at ring idx 2,3,0,1
    pool = write_chunk_pages(pool, k_new, k_new, jnp.int32(0),
                             jnp.int32(6), tables, window=4)
    assert bool(jnp.all(pool.k[0, 0] == 3.0))     # pos 2 -> phys 0 off 0
    assert bool(jnp.all(pool.k[0, 1] == 4.0))
    assert bool(jnp.all(pool.k[1, 0] == 5.0))     # pos 4 wraps
    assert bool(jnp.all(pool.k[1, 1] == 6.0))


# ----------------------------------------------------------------------
# Prefix gather + in-chunk merge vs the dense causal oracle (fast)
# ----------------------------------------------------------------------


def _linear_pool(k, v, off, ps, rng):
    """Prefix positions 0..off-1 scattered into shuffled pages."""
    b, _, hkv, hd = k.shape
    npp = -(-off // ps)
    n_pages = b * npp
    perm = rng.permutation(n_pages)
    tables = perm.reshape(b, npp).astype(np.int32)
    pool_k = np.zeros((n_pages + 1, ps, hkv, hd), np.float32)
    pool_v = np.zeros_like(pool_k)
    for bi in range(b):
        for p in range(off):
            pool_k[tables[bi, p // ps], p % ps] = np.asarray(k[bi, p])
            pool_v[tables[bi, p // ps], p % ps] = np.asarray(v[bi, p])
    return jnp.asarray(pool_k), jnp.asarray(pool_v), jnp.asarray(tables)


def _ring_pool(k, v, off, window, ps):
    """Prefix scattered the way successive chunk writes leave a ring:
    slot r holds the newest position ≡ r (mod window) below off."""
    b, _, hkv, hd = k.shape
    n_ring = max(window // ps, 1)
    n_pages = b * n_ring
    tables = np.arange(n_pages).reshape(b, n_ring).astype(np.int32)
    pool_k = np.zeros((n_pages + 1, ps, hkv, hd), np.float32)
    pool_v = np.zeros_like(pool_k)
    for bi in range(b):
        for p in range(max(0, off - window), off):
            r = p % window
            pool_k[tables[bi, r // ps], r % ps] = np.asarray(k[bi, p])
            pool_v[tables[bi, r // ps], r % ps] = np.asarray(v[bi, p])
    return jnp.asarray(pool_k), jnp.asarray(pool_v), jnp.asarray(tables)


@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("off", [0, 5, 13])
def test_chunk_attention_matches_dense_causal(window, off, rng):
    """prefix-page gather (+ per-query causal/window offsets) merged
    with the in-chunk causal partial == one dense causal pass over the
    whole sequence, for global and sliding-window layers, including an
    empty prefix (the first chunk)."""
    key = jax.random.PRNGKey(off * 10 + window)
    b, hq, hkv, hd, ps = 2, 4, 2, 8, 4
    total = off + 11                                       # chunk of 11
    sc = total - off
    q = jax.random.normal(key, (b, hq, sc, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, total, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, total, hkv, hd))
    kh, vh = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    limit = jnp.full((b,), total)
    ref, _ = _chunked_fwd(q, kh, vh, limit, causal=True, window=window,
                          q_offset=off, chunk=1024)

    kc = kh[:, :, off:]
    vc = vh[:, :, off:]
    out_c, lse_c = _chunked_fwd(q, kc, vc, jnp.full((b,), sc),
                                causal=True, window=window, q_offset=0,
                                chunk=1024)
    if window:
        pool_k, pool_v, tables = _ring_pool(k, v, off, window, ps)
    else:
        pool_k, pool_v, tables = _linear_pool(k, v, max(off, 1), ps, rng)
    offs = jnp.full((b,), off, jnp.int32)
    out_p, lse_p = _paged_fwd(q, pool_k, pool_v, tables, offs,
                              chunk=1024, q_offset=offs, window=window)
    out = _merge_partials(out_c, lse_c, out_p, lse_p)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


# ----------------------------------------------------------------------
# Traffic model + engine config validation (fast)
# ----------------------------------------------------------------------


def test_chunked_prefill_traffic_model():
    row = 2 * 2 * 8 * 2                       # Hkv=2, hd=8, bf16
    out = chunked_prefill_traffic(70, chunk_size=32, page_size=16,
                                  n_global=3, n_kv_heads=2, head_dim=8)
    assert out["n_chunks"] == 3
    # the removed stall: one 70-row program -> at most a 32-row panel
    assert out["stall_rows_one_shot"] == 70
    assert out["stall_rows_chunked"] == 32
    # re-read: chunk 1 re-gathers 32 prefix rows, chunk 2 re-gathers 64
    assert out["prefix_reread_bytes"] == 3 * (32 + 64) * row
    # a prompt that fits one chunk pays nothing and removes nothing
    one = chunked_prefill_traffic(20, chunk_size=32, page_size=16,
                                  n_global=3, n_kv_heads=2, head_dim=8)
    assert one["n_chunks"] == 1 and one["prefix_reread_bytes"] == 0
    assert one["stall_rows_chunked"] == one["stall_rows_one_shot"] == 20
    # windowed layers re-read at most the ring
    cfg = REDUCED["gemma3-27b"]()             # window=16
    g = chunked_prefill_traffic_cfg(cfg, 64, chunk_size=16, page_size=8)
    grow = 2 * cfg.n_kv_heads * cfg.head_dim * 2
    from repro.core.block_traffic import kv_layer_counts
    n_global, n_local, _ = kv_layer_counts(cfg)
    want = (n_global * (16 + 32 + 48) + n_local * (16 + 16 + 16)) * grow
    assert g["prefix_reread_bytes"] == want


def test_engine_rejects_bad_chunk_config():
    key = jax.random.PRNGKey(0)
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    # off the bucket ladder: compile count would be unbounded
    with pytest.raises(ValueError):
        Engine(params, cfg, n_slots=2, max_len=64,
               paging=PagingConfig(prefill_chunk=24))
    # recurrent state cannot be split across chunk forwards
    rcfg = REDUCED["rwkv6-3b"]()
    rparams, _ = lm.init_lm(key, rcfg, dtype=jnp.float32)
    with pytest.raises(ValueError):
        Engine(rparams, rcfg, n_slots=2, max_len=64,
               paging=PagingConfig(prefill_chunk=16))
    eng = Engine(params, cfg, n_slots=2, max_len=64,
                 paging=PagingConfig(prefill_chunk=16))
    assert eng.prefill_chunk == 16


# ----------------------------------------------------------------------
# Engine parity: chunked == one-shot bucketed greedy streams (slow)
# ----------------------------------------------------------------------


def _greedy_engine_run(params, cfg, prompts, *, chunk, max_len, n_new,
                       page_size=8, n_slots=2):
    eng = Engine(params, cfg, n_slots=n_slots, max_len=max_len, eos_id=-1,
                 paging=PagingConfig(page_size=page_size,
                                     prefill_chunk=chunk))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=n_new))
    done = eng.run()
    return eng, {c.rid: c for c in done}


@pytest.mark.slow
@pytest.mark.parametrize("chunk", [16, 32])
def test_chunked_matches_one_shot_mixed_lengths(chunk):
    """Greedy streams are identical to the dense-cache oracle across
    prompts that are shorter than the chunk (one-shot path), equal to
    it, a multiple of it, and not divisible by it — with more requests
    than slots so chunked admissions interleave with decode."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    plens = [3, chunk, chunk + 5, 2 * chunk, 37, 50]
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (p,), 0,
                                  cfg.vocab) for i, p in enumerate(plens)]
    n_new = 5
    eng, by_rid = _greedy_engine_run(params, cfg, prompts, chunk=chunk,
                                     max_len=96, n_new=n_new)
    assert sorted(by_rid) == list(range(len(prompts)))
    for i, p in enumerate(prompts):
        want = manual_greedy(params, cfg, p, n_new, 96)
        assert by_rid[i].tokens == want, (i, by_rid[i].tokens, want)
    # chunked completions carry TTFT + full inter-token latency trails
    for c in by_rid.values():
        assert c.ttft_s > 0 and len(c.itl_s) == len(c.tokens) - 1
    # prompts <= chunk took the one-shot path; longer ones chunked
    assert eng._chunk_shapes and eng._prefill_lens
    # the auditor's static enumeration predicts the jit caches exactly
    from repro.analysis import compile_bound
    expected = compile_bound.predict_compile_counts(
        plens, max_len=96, prefill_chunk=chunk)
    assert eng.compile_counts() == expected
    assert compile_bound.check_engine_counts(eng, expected).ok


@pytest.mark.slow
def test_chunked_sliding_window_ring_wrap_mid_prompt():
    """gemma3-style local/global mix: prompts longer than the window
    chunk-prefill across the ring wrap (later chunks' prefix gathers
    recover ring positions), and decode continues past it — token
    streams must equal the dense ring-cache oracle."""
    cfg = REDUCED["gemma3-27b"]()                 # window=16
    key = jax.random.PRNGKey(1)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    plens = [40, 20, 5, 33]                       # 40/33 wrap mid-prompt
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (p,), 0,
                                  cfg.vocab) for i, p in enumerate(plens)]
    n_new = 6
    _, by_rid = _greedy_engine_run(params, cfg, prompts, chunk=16,
                                   max_len=64, n_new=n_new)
    for i, p in enumerate(prompts):
        want = manual_greedy(params, cfg, p, n_new, 64)
        assert by_rid[i].tokens == want, (i, by_rid[i].tokens, want)


@pytest.mark.slow
def test_chunked_plen_eq_max_len_edge():
    """A prompt of exactly max_len chunk-prefills to the last page and
    retires at the final chunk with the prefill-sampled token (the PR 4
    prefill-only clamp), releasing every page."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(5)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    full_p = jax.random.randint(jax.random.fold_in(key, 9), (32,), 0,
                                cfg.vocab)
    eng, by_rid = _greedy_engine_run(params, cfg, [full_p], chunk=16,
                                     max_len=32, n_new=5)
    assert by_rid[0].tokens == manual_greedy(params, cfg, full_p, 1, 32)
    assert len(by_rid[0].tokens) == 1
    assert eng.pool.live_pages() == 0
    assert len(eng._chunk_shapes) == 1            # both chunks shape 16
