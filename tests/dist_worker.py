"""Multi-device scenarios run in a subprocess with 8 host devices.

Invoked by test_dist.py:  python tests/dist_worker.py <scenario>
Exit code 0 = pass. Prints diagnostics on failure.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                               # noqa: E402
import jax.numpy as jnp                                  # noqa: E402
import numpy as np                                       # noqa: E402

from repro.configs import REDUCED                        # noqa: E402
from repro.core import partitioning                     # noqa: E402
from repro.launch import specs as specs_lib              # noqa: E402
from repro.models import lm                              # noqa: E402
from repro.train import step as tsl                      # noqa: E402


def _mesh222():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


def _setup(arch="deepseek-7b", b=4, s=32):
    cfg = REDUCED[arch]()
    key = jax.random.PRNGKey(0)
    params, pspecs = lm.init_lm(key, cfg, dtype=jnp.float32)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    return cfg, params, pspecs, batch


def scenario_fsdp_matches_single():
    """Sharded train step == unsharded step, bit-for-bit-ish."""
    cfg, params, pspecs, batch = _setup()
    tcfg = tsl.TrainConfig(remat=True)
    step = tsl.make_train_step(cfg, tcfg)
    # single device reference
    state0 = tsl.init_state(params, tcfg)
    ref_state, ref_metrics = jax.jit(step)(state0, batch)

    mesh = _mesh222()
    with partitioning.use_mesh(mesh):
        state_specs = tsl.state_logical_specs(pspecs, tcfg)
        state = tsl.init_state(params, tcfg)
        state_sh = partitioning.tree_shardings(mesh, state_specs,
                                               like=state)
        state = jax.device_put(state, state_sh)
        batch_sh = {k: partitioning.named_sharding(
            mesh, "batch", *([None] * (v.ndim - 1)), shape=v.shape)
            for k, v in batch.items()}
        batch_d = jax.device_put(batch, batch_sh)
        jstep = jax.jit(step, in_shardings=(state_sh, batch_sh),
                        out_shardings=(state_sh, None))
        new_state, metrics = jstep(state, batch_d)
    dl = abs(float(metrics["loss"]) - float(ref_metrics["loss"]))
    assert dl < 1e-4, f"loss mismatch {dl}"
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(ref_state.params),
        jax.tree.leaves(jax.device_get(new_state.params)))]
    assert max(diffs) < 1e-4, f"param mismatch {max(diffs)}"
    print("fsdp ok: dloss", dl, "max dparam", max(diffs))


def scenario_moe_ep_matches_local():
    """shard_map expert-parallel dispatch == local dispatch."""
    from repro.models import moe
    cfg = REDUCED["phi3.5-moe-42b-a6.6b"]()
    key = jax.random.PRNGKey(0)
    params, _ = moe.init(key, cfg, stack=None, dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model))
    out_local, aux_local = moe._apply_local(params, x, cfg=cfg)
    mesh = _mesh222()
    with partitioning.use_mesh(mesh):
        # batch 4 over (pod=2, data=2); model=2 divides padded experts (4)
        fn = jax.jit(lambda p, xx: moe.apply(p, xx, cfg=cfg))
        out_ep, aux_ep = fn(params, x)
    d = float(jnp.max(jnp.abs(out_local - jax.device_get(out_ep))))
    # capacity is computed per shard in EP (tokens/shard) vs global in
    # local mode; with the smoke capacity_factor=4 no tokens drop.
    assert d < 1e-4, f"moe mismatch {d}"
    da = abs(float(aux_local) - float(aux_ep))
    assert da < 1e-5, f"aux mismatch {da}"
    print("moe ep ok:", d, da)


def scenario_compressed_pods_close():
    """int8+EF cross-pod gradient compression stays close to exact and
    the error-feedback residual is populated."""
    cfg, params, pspecs, batch = _setup(b=8, s=16)
    mesh = _mesh222()
    t_exact = tsl.TrainConfig(remat=False)
    t_comp = tsl.TrainConfig(remat=False, compress_pods=True)
    step_e = tsl.make_train_step(cfg, t_exact)
    step_c = tsl.make_train_step(cfg, t_comp, mesh=mesh)
    with partitioning.use_mesh(mesh):
        se = tsl.init_state(params, t_exact)
        sc = tsl.init_state(params, t_comp)
        ne, me = jax.jit(step_e)(se, batch)
        nc, mc = jax.jit(step_c)(sc, batch)
    assert abs(float(me["loss"]) - float(mc["loss"])) < 1e-4
    # parameters after one step: compression is lossy but close
    rel = [float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9))
           for a, b in zip(jax.tree.leaves(ne.params),
                           jax.tree.leaves(nc.params))]
    assert max(rel) < 0.1, f"compressed step diverged: {max(rel)}"
    res_norm = sum(float(jnp.sum(jnp.abs(r)))
                   for r in jax.tree.leaves(nc.residual))
    assert res_norm > 0, "error-feedback residual empty"
    print("compression ok: max rel", max(rel), "residual", res_norm)


def scenario_elastic_restore():
    """Checkpoint saved under mesh (2,2,2) restores onto mesh (4,2)."""
    import tempfile

    from repro.checkpoint import checkpointer as ckpt
    cfg, params, pspecs, batch = _setup()
    tcfg = tsl.TrainConfig()
    state = tsl.init_state(params, tcfg)
    mesh_a = _mesh222()
    with partitioning.use_mesh(mesh_a):
        specs_tree = tsl.state_logical_specs(pspecs, tcfg)
        sh_a = partitioning.tree_shardings(mesh_a, specs_tree, like=state)
        state_a = jax.device_put(state, sh_a)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, state_a, extra={"data_step": 7})
        mesh_b = jax.make_mesh((4, 2), ("data", "model"))
        with partitioning.use_mesh(mesh_b):
            sh_b = partitioning.tree_shardings(mesh_b, specs_tree,
                                               like=state)
            restored, extra = ckpt.restore(d, 7, state, shardings=sh_b)
        assert extra["data_step"] == 7
        for a, b in zip(jax.tree.leaves(state_a),
                        jax.tree.leaves(restored)):
            np.testing.assert_allclose(np.asarray(jax.device_get(a)),
                                       np.asarray(jax.device_get(b)),
                                       rtol=0, atol=0)
    print("elastic ok")


def scenario_seq_sharded_decode():
    """Sequence-sharded flash decode == unsharded decode numerics."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(5)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    b, s_prefill, alloc = 2, 12, 32
    tokens = jax.random.randint(key, (b, s_prefill + 6), 0, cfg.vocab)
    # reference: no mesh
    lg_ref, cache_ref = lm.prefill(params, tokens[:, :s_prefill], cfg,
                                   alloc=alloc)
    lengths = jnp.full((b,), s_prefill, jnp.int32)
    refs = []
    for t in range(s_prefill, s_prefill + 6):
        lg_ref, cache_ref = lm.decode_step(
            params, cache_ref, tokens[:, t:t + 1], lengths, cfg)
        refs.append(lg_ref)
        lengths = lengths + 1

    mesh = _mesh222()
    rules = {"kv_seq": "model", "decode_attn": "sharded"}
    with partitioning.use_mesh(mesh, rules):
        lg, cache = jax.jit(
            lambda p, tk: lm.prefill(p, tk, cfg, alloc=alloc))(
                params, tokens[:, :s_prefill])
        lengths = jnp.full((b,), s_prefill, jnp.int32)
        step = jax.jit(lambda p, c, tk, ln: lm.decode_step(p, c, tk, ln,
                                                           cfg))
        for i, t in enumerate(range(s_prefill, s_prefill + 6)):
            lg, cache = step(params, cache, tokens[:, t:t + 1], lengths)
            err = float(jnp.max(jnp.abs(lg - refs[i])))
            assert err < 1e-3, f"step {i}: {err}"
            lengths = lengths + 1
    print("seq-sharded decode ok")


def scenario_dryrun_small():
    """The dry-run machinery end-to-end on the host mesh: lower+compile
    a reduced arch with the production logical rules."""
    cfg = REDUCED["gemma3-27b"]()
    mesh = _mesh222()
    from repro.core.types import ShapeSpec
    shape = ShapeSpec("train_small", "train", seq_len=32, global_batch=4)
    from repro.launch import dryrun
    with partitioning.use_mesh(mesh, dryrun.cell_rules(cfg, shape)):
        fn, args, in_sh, out_sh, donate = dryrun._sharding_trees(
            mesh, cfg, shape, tsl.TrainConfig())
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*args).compile()
    from repro.launch import hlo_cost
    cost = hlo_cost.analyze_hlo(compiled.as_text())
    assert cost.flops > 0
    print("dryrun-small ok: flops", cost.flops)


if __name__ == "__main__":
    name = sys.argv[1]
    globals()[f"scenario_{name}"]()
    print("PASS", name)
