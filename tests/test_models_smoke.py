"""Per-architecture smoke tests: REDUCED config, one forward + train
step on CPU, asserting output shapes and no NaNs (per the brief)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REDUCED
from repro.configs.swin_t import ViTConfig, reduced as swin_reduced
from repro.models import lm, vision
from repro.train import step as train_step_lib

pytestmark = pytest.mark.slow  # per-arch init + jit, ~2 min total on CPU

ARCH_IDS = sorted(REDUCED)


def _batch(cfg, key, b=2, s=32):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.cross_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = REDUCED[arch]()
    key = jax.random.PRNGKey(0)
    params, specs = lm.init_lm(key, cfg, dtype=jnp.float32)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple)
        and all(e is None or isinstance(e, str) for e in x))
    batch = _batch(cfg, key)
    logits, aux = lm.forward(params, batch["tokens"], cfg,
                             extra={k: v for k, v in batch.items()
                                    if k not in ("tokens", "labels")}
                             or None, remat=False)
    assert logits.shape == (2, 32, lm.padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = REDUCED[arch]()
    key = jax.random.PRNGKey(1)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    tcfg = train_step_lib.TrainConfig(microbatches=1, remat=True,
                                      total_steps=10, warmup_steps=2)
    state = train_step_lib.init_state(params, tcfg)
    step = train_step_lib.make_train_step(cfg, tcfg)
    state, metrics = jax.jit(step)(state, _batch(cfg, key))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["skipped"]) == 0.0
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_swin_smoke():
    cfg = swin_reduced()
    key = jax.random.PRNGKey(0)
    p = vision.init_swin(key, cfg)
    img = jax.random.normal(key, (2, cfg.img_size, cfg.img_size, 3))
    logits = vision.swin_forward(p, img, cfg)
    assert logits.shape == (2, cfg.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_vit_smoke():
    cfg = ViTConfig(img_size=32, patch=8, embed_dim=64, depth=2,
                    num_heads=4, num_classes=10)
    key = jax.random.PRNGKey(0)
    p = vision.init_vit(key, cfg)
    img = jax.random.normal(key, (2, 32, 32, 3))
    logits = vision.vit_forward(p, img, cfg)
    assert logits.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_microbatch_accumulation_matches_full_batch():
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(2)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    batch = _batch(cfg, key, b=4)
    t1 = train_step_lib.TrainConfig(microbatches=1, remat=False)
    t4 = train_step_lib.TrainConfig(microbatches=4, remat=False)
    g1, m1 = train_step_lib._grads_and_metrics(params, batch, cfg, t1)
    g4, m4 = train_step_lib._grads_and_metrics(params, batch, cfg, t4)
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)))
    assert diff < 1e-5
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
