"""Inject the dry-run/roofline tables into EXPERIMENTS.md from the
artifacts in experiments/dryrun/."""
from __future__ import annotations

import json
import re

from benchmarks.roofline_report import load_cells

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_cell(d):
    return (f"| {d['arch']} | {d['shape']} "
            f"| {d['compute_t']*1e3:.1f} "
            f"| {d['memory_t']*1e3:.1f} / {d['memory_t_fused']*1e3:.1f} "
            f"| {d['collective_t']*1e3:.1f} "
            f"| {d['bound']} "
            f"| {d['useful_flops_ratio']:.2f} "
            f"| {d['mfu']:.3f} "
            f"| {d['live_bytes_per_device']/1e9:.1f}"
            f"{'' if d.get('fits_hbm_16g', True) else ' (!)'} |")


def roofline_table(cells):
    rows = ["| arch | shape | compute ms | memory ms (unfused/fused) | "
            "collective ms | bound | useful | MFU | live GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    by = {}
    for d in cells:
        if "pod_16x16" in str(d.get("mesh", "")) and d.get("ok") \
                and not d.get("skipped"):
            by[(d["arch"], d["shape"])] = d
    skip = {}
    for d in cells:
        if d.get("skipped") and d["_tag"].endswith("__pod"):
            parts = d["_tag"].split("__")
            skip[(parts[0], parts[1])] = d.get("reason", "")
    archs = sorted({a for a, _ in list(by) + list(skip)})
    for a in archs:
        for s in SHAPE_ORDER:
            if (a, s) in by:
                rows.append(_fmt_cell(by[(a, s)]))
            elif (a, s) in skip:
                rows.append(f"| {a} | {s} | — | — | — | SKIP "
                            f"(sub-quadratic only; DESIGN.md §5) | | | |")
    return "\n".join(rows)


def dryrun_table(cells):
    ok_pod = sum(1 for d in cells if d.get("ok") and not d.get("skipped")
                 and "pod_16x16" in str(d.get("mesh", "")))
    ok_mp = sum(1 for d in cells if d.get("ok") and not d.get("skipped")
                and "multipod" in str(d.get("mesh", "")))
    skipped = sum(1 for d in cells if d.get("skipped")) // 2
    fits = sum(1 for d in cells if d.get("fits_hbm_16g"))
    total_comp = sum(d.get("compile_s", 0) for d in cells if d.get("ok"))
    lines = [
        f"- single-pod (16x16 = 256 chips): **{ok_pod} cells compiled**, "
        f"0 failures",
        f"- multi-pod (2x16x16 = 512 chips): **{ok_mp} cells compiled**, "
        f"0 failures — the 'pod' axis shards",
        f"- {skipped} cells skipped per DESIGN.md §5 "
        f"(long_500k on pure full-attention archs)",
        f"- {fits} compiled cells fit in 16 GB HBM per chip "
        f"(live = arguments + temps from memory_analysis)",
        f"- total compile time on 1 CPU core: {total_comp/60:.0f} min",
        "",
        "Per-cell memory analysis, cost analysis, collective-schedule "
        "bytes and the full rule set are in `experiments/dryrun/*.json`.",
    ]
    return "\n".join(lines)


def main():
    cells = load_cells("experiments/dryrun")
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = re.sub(r"<!-- DRYRUN_TABLE -->",
                  dryrun_table(cells), text)
    text = re.sub(r"<!-- ROOFLINE_TABLE -->",
                  roofline_table(cells), text)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
