"""CI gate for bench artifacts: fail if any JSON misses required keys.

Each PR's bench writes a ``BENCH_PRn.json`` artifact; downstream
sessions (and the README tables) read specific top-level sections from
them. A bench refactor that silently drops a section would only show up
when a later consumer breaks, so CI runs this checker after the bench
loop: for every artifact it verifies the file exists, parses as JSON,
and carries its required top-level keys.

Usage: ``python benchmarks/check_bench.py [dir]`` (default: cwd).
Exits non-zero listing every missing file/key.
"""
from __future__ import annotations

import json
import os
import sys

REQUIRED = {
    "BENCH_PR2.json": ("traffic", "wall_us", "pallas_calls"),
    "BENCH_PR3.json": ("throughput", "kv_traffic", "compiles", "config"),
    "BENCH_PR4.json": ("weight_traffic", "jaxpr", "wall_us"),
    "BENCH_PR5.json": ("off", "on", "p95_ttft_improves", "modeled",
                       "config"),
    "BENCH_PR6.json": ("parity", "scaling", "traffic", "compiles",
                       "config"),
    "BENCH_PR7.json": ("goodput", "preemptions", "recompute", "statuses",
                       "config"),
    "BENCH_PR8.json": ("hit_rate", "flops", "live_pages", "ttft",
                       "parity", "compiles", "config"),
    "BENCH_PR9.json": ("passes", "compiles", "config"),
    "BENCH_PR10.json": ("acceptance", "traffic", "parity", "compiles",
                        "config"),
}


def check(directory: str = ".") -> list[str]:
    problems = []
    for name, keys in sorted(REQUIRED.items()):
        path = os.path.join(directory, name)
        if not os.path.exists(path):
            problems.append(f"{name}: artifact missing")
            continue
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"{name}: unreadable ({e})")
            continue
        if not isinstance(data, dict):
            problems.append(f"{name}: top level is {type(data).__name__},"
                            " expected object")
            continue
        missing = [k for k in keys if k not in data]
        if missing:
            problems.append(f"{name}: missing keys {missing}")
    return problems


def main() -> int:
    directory = sys.argv[1] if len(sys.argv) > 1 else "."
    problems = check(directory)
    if problems:
        for p in problems:
            print(f"check_bench: {p}", file=sys.stderr)
        return 1
    print(f"check_bench: {len(REQUIRED)} artifacts OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
