"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the PR 2
block-pipeline artifact (BENCH_PR2.json), the PR 3 paged-serving
artifact (BENCH_PR3.json), the PR 4 decode weight-traffic artifact
(BENCH_PR4.json), the PR 5 chunked-prefill TTFT artifact
(BENCH_PR5.json), the PR 7 preemption-pressure artifact
(BENCH_PR7.json), the PR 8 prefix-cache artifact (BENCH_PR8.json),
the PR 9 static-auditor artifact (BENCH_PR9.json), the PR 10
self-speculative-decoding artifact (BENCH_PR10.json)
and the PR 6 tensor-parallel artifact
(BENCH_PR6.json — run as a subprocess: the emulated mesh needs
XLA_FLAGS set before jax initialises, which has already happened in
this process).
"""
from __future__ import annotations

import os
import subprocess
import sys


def main() -> None:
    from benchmarks.analysis_bench import analysis_bench
    from benchmarks.block_bench import block_bench
    from benchmarks.decode_bench import decode_bench
    from benchmarks.kernel_bench import kernel_suite
    from benchmarks.paper_tables import ALL
    from benchmarks.roofline_report import roofline_report
    from benchmarks.serve_bench import (chunked_prefill_bench,
                                        preemption_bench,
                                        prefix_cache_bench, serve_bench)
    from benchmarks.spec_bench import spec_bench

    rows = []

    def emit(name, us, derived):
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}")

    print("name,us_per_call,derived")
    for bench in ALL:
        bench(emit)
    kernel_suite(emit)
    roofline_report(emit)
    block_bench(emit, json_path="BENCH_PR2.json")
    serve_bench(emit, json_path="BENCH_PR3.json")
    decode_bench(emit, json_path="BENCH_PR4.json")
    chunked_prefill_bench(emit, json_path="BENCH_PR5.json")
    preemption_bench(emit, json_path="BENCH_PR7.json")
    prefix_cache_bench(emit, json_path="BENCH_PR8.json")
    analysis_bench(emit, json_path="BENCH_PR9.json")
    spec_bench(emit, json_path="BENCH_PR10.json")
    sys.stdout.flush()
    tp = subprocess.run(
        [sys.executable,
         os.path.join(os.path.dirname(__file__), "tp_bench.py"),
         "BENCH_PR6.json"])
    if tp.returncode != 0:
        raise SystemExit(tp.returncode)


if __name__ == "__main__":
    main()
