"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks.kernel_bench import kernel_suite
    from benchmarks.paper_tables import ALL
    from benchmarks.roofline_report import roofline_report

    rows = []

    def emit(name, us, derived):
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}")

    print("name,us_per_call,derived")
    for bench in ALL:
        bench(emit)
    kernel_suite(emit)
    roofline_report(emit)
    sys.stdout.flush()


if __name__ == "__main__":
    main()
