"""Benchmarks reproducing each paper table/figure.

  fig2   — FLOPs/parameter distribution in Swin-T (conv/FC/attention)
  table3 — peak throughput/area-class comparison (ASIC analytical model)
  table4 — Swin-T images/s: paper ASIC vs our reproduction vs the
           row-wise TPU schedule estimate
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.swin_t import CONFIG as SWIN_T
from repro.core.asic_model import ASIC, run_asic, swin_ops, swin_params
from repro.core.rowwise import V5E, schedule_model
from repro.kernels import ops


def _time_call(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6   # us


def fig2_distribution(emit):
    rep = run_asic(swin_ops(SWIN_T))
    shares = rep.flops_shares()
    p = swin_params(SWIN_T)
    pt = sum(p.values())
    emit("fig2.flops_fc_share", 0, f"{shares['fc']:.4f}")
    emit("fig2.flops_conv_share", 0, f"{shares['conv']:.4f}")
    emit("fig2.flops_attn_share", 0, f"{shares['attn']:.4f}")
    emit("fig2.params_fc_share", 0, f"{p['fc'] / pt:.4f}")
    emit("fig2.claim_fc_flops_ge_0.97", 0,
         str(shares["fc"] >= 0.95))
    emit("fig2.claim_fc_params_ge_0.83", 0, str(p["fc"] / pt >= 0.83))


def table3_throughput(emit):
    emit("table3.peak_gops_paper", 0, "403.2")
    emit("table3.peak_gops_model", 0, f"{ASIC.peak_gops:.1f}")
    emit("table3.pe_count", 0, str(ASIC.macs))
    # our TPU row-wise schedule: utilization over the same Swin-T GEMMs
    sched = schedule_model(swin_ops(SWIN_T))
    emit("table3.tpu_rowwise_utilization", 0,
         f"{sched.utilization:.4f}")
    # kernel microbench: the dot-product primitive on this host (XLA)
    x = jnp.ones((3136, 96), jnp.float32)
    w = jnp.ones((96, 288), jnp.float32)
    f = jax.jit(lambda a, b: ops.matmul(a, b, impl="ref"))
    us = _time_call(f, x, w)
    gflops = 2 * 3136 * 96 * 288 / (us * 1e-6) / 1e9
    emit("table3.rowwise_matmul_host", us, f"{gflops:.1f} GFLOP/s")


def table4_swin_throughput(emit):
    rep = run_asic(swin_ops(SWIN_T))
    emit("table4.paper_img_s", 0, "44.5")
    emit("table4.model_img_s", 0, f"{rep.images_per_s:.1f}")
    emit("table4.model_latency_ms", 0, f"{rep.time_s * 1e3:.2f}")
    emit("table4.model_utilization", 0, f"{rep.utilization:.4f}")
    emit("table4.gpu_reference_img_s", 0, "41.5")
    # v5e roofline estimate for the same workload under the row-wise
    # schedule (compute-bound term; int8 doubles MXU throughput)
    macs = rep.total_macs
    t_v5e = 2 * macs / V5E.peak_bf16_flops
    emit("table4.v5e_rowwise_img_s_bf16", 0, f"{1 / t_v5e:.0f}")


ALL = [fig2_distribution, table3_throughput, table4_swin_throughput]
