"""PR 2 bench: block-forward HBM traffic + wall time, fused vs unfused.

Emits ``bench.block.*`` CSV rows and writes ``BENCH_PR2.json`` (uploaded
as a CI artifact) with three sections:

  * ``traffic``      — modeled bytes for one Swin-T block per stage,
                       fused pipeline vs the seed's per-op composition
                       (``core/block_traffic.py``).
  * ``wall_us``      — measured wall time of the reduced-Swin forward,
                       fused vs unfused, on this host's default impl.
  * ``pallas_calls`` — kernel launches per attn+MLP sublayer pair from
                       the traced jaxpr (interpret impl), fused vs
                       unfused; "dense_pipeline" excludes the
                       attention-core kernel (present once in both).
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.swin_t import reduced as swin_reduced
from repro.core import runtime
from repro.core.block_traffic import swin_block_traffic, swin_t_stage_cases
from repro.core.types import BlockDef, ModelConfig
from repro.models import blocks, vision


def _traffic():
    out = {}
    for name, kw in swin_t_stage_cases().items():
        for shifted in (False, True):
            key = f"swin_t_{name}" + ("_shifted" if shifted else "")
            tf = swin_block_traffic(**kw, shifted=shifted, fused=True)
            tu = swin_block_traffic(**kw, shifted=shifted, fused=False)
            out[key] = {
                "fused_bytes": tf["total"],
                "unfused_bytes": tu["total"],
                "ratio": tu["total"] / tf["total"],
                "fused_ops": dict(tf["ops"]),
                "unfused_ops": dict(tu["ops"]),
            }
    return out


def _wall_us(iters: int = 3):
    cfg = swin_reduced()
    key = jax.random.PRNGKey(0)
    params = vision.init_swin(key, cfg)
    img = jax.random.normal(key, (2, cfg.img_size, cfg.img_size, 3),
                            jnp.float32)
    # Record the impl: on CPU hosts this resolves to 'ref' (pure XLA
    # compositions both ways), so the wall numbers measure trace/compile
    # structure, not kernel fusion — the traffic model is the perf
    # evidence there.
    out = {"impl": runtime.resolve_impl()}
    for fused in (True, False):
        with runtime.use_pipeline_fusion(fused):
            fn = jax.jit(lambda p, im: vision.swin_forward(p, im, cfg))
            jax.block_until_ready(fn(params, img))
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(fn(params, img))
            out["fused" if fused else "unfused"] = (
                (time.perf_counter() - t0) / iters * 1e6)
    return out


def sublayer_pallas_calls(fused: bool) -> int:
    """Kernel launches for one attn + gated-MLP sublayer pair, counted
    from the traced jaxpr (interpret impl, no execution). Shared by the
    BENCH_PR2 artifact and the acceptance test — the count includes the
    attention-core kernel (subtract 1 for the dense pipeline alone)."""
    cfg = ModelConfig(name="bench", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                      act="silu", norm="rms")
    blk = BlockDef(mixer="attn", ffn="mlp")
    key = jax.random.PRNGKey(0)
    params, _ = blocks.init_block(key, blk, cfg, None, jnp.float32)
    x = jnp.zeros((2, 16, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    with runtime.use_impl("interpret"), runtime.use_pipeline_fusion(fused):
        jaxpr = jax.make_jaxpr(lambda p, a: blocks.apply_block(
            blk, p, a, cfg=cfg, mode="train", positions=pos)[0])(params, x)
    return str(jaxpr).count("pallas_call")


def _pallas_calls():
    out = {}
    for fused in (True, False):
        total = sublayer_pallas_calls(fused)
        tag = "fused" if fused else "unfused"
        out[f"{tag}_total"] = total
        out[f"{tag}_dense_pipeline"] = total - 1       # minus attn core
    return out


def block_bench(emit, json_path=None):
    traffic = _traffic()
    for key, row in traffic.items():
        emit(f"bench.block.{key}", 0,
             f"fused={row['fused_bytes']} unfused={row['unfused_bytes']} "
             f"ratio={row['ratio']:.3f}")
    wall = _wall_us()
    emit("bench.block.swin_reduced_fused", wall["fused"], "wall us")
    emit("bench.block.swin_reduced_unfused", wall["unfused"], "wall us")
    calls = _pallas_calls()
    emit("bench.block.pallas_calls", 0,
         f"fused={calls['fused_total']} unfused={calls['unfused_total']} "
         f"dense_pipeline {calls['fused_dense_pipeline']}"
         f"<-{calls['unfused_dense_pipeline']}")
    result = {"traffic": traffic, "wall_us": wall, "pallas_calls": calls}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main():
    json_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_PR2.json"

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")

    block_bench(emit, json_path=json_path)
    print(f"wrote {json_path}")


if __name__ == "__main__":
    main()
