"""PR 9 bench: static-auditor cost and enumeration accuracy.

Emits ``bench.analysis.*`` CSV rows and writes ``BENCH_PR9.json``
(uploaded as a CI artifact) with three sections:

  * ``passes``   — per-pass wall time, invariant sites checked, and
    diagnostic count for a full single-device audit of the shipped
    serving entry points — the CI gate's exact workload, so this is
    the gate's cost ledger.
  * ``compiles`` — statically enumerated program counts
    (``predict_compile_counts``) vs the jit caches of a real
    mixed-traffic engine run: the acceptance criterion that the
    enumeration is exact, measured rather than asserted.
  * ``config``   — audited arch and engine geometry.
"""
from __future__ import annotations

import json

import jax


def analysis_bench(emit, json_path=None):
    from repro.analysis import compile_bound
    from repro.analysis.audit import build_engine, run_passes
    from repro.serve.engine import Request

    results = run_passes("deepseek-7b", 1)
    passes = {}
    for r in results:
        passes[r.name] = {"wall_us": r.wall_s * 1e6,
                          "checked": r.checked,
                          "diagnostics": len(r.diagnostics),
                          "ok": r.ok}
        emit(f"bench.analysis.{r.name}", r.wall_s * 1e6,
             f"checked={r.checked};ok={r.ok}")

    # enumeration accuracy on live traffic: mixed one-shot and chunked
    # prompts spanning every bucket of the audited geometry
    eng, cfg = build_engine("deepseek-7b", 1)
    plens = [3, 16, 17, 21, 33, 40, 5, 50]
    key = jax.random.PRNGKey(1)
    for i, plen in enumerate(plens):
        eng.submit(Request(rid=i, prompt=jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 0, cfg.vocab),
            max_new=4))
    eng.run()
    actual = eng.compile_counts()
    predicted = compile_bound.predict_compile_counts(
        plens, max_len=eng.max_len, prefill_chunk=eng.prefill_chunk)
    inv = compile_bound.enumerate_programs(
        max_len=eng.max_len, page_size=eng.page_size,
        prefill_chunk=eng.prefill_chunk)
    match = actual == predicted
    emit("bench.analysis.compiles", float(sum(actual.values())),
         f"predicted={sum(predicted.values())};"
         f"bound={inv.bound};match={match}")

    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "passes": passes,
                "compiles": {"actual": actual, "predicted": predicted,
                             "enumerated_bound": inv.bound,
                             "match": match},
                "config": {"arch": cfg.name, "mesh": 1,
                           "n_slots": eng.n_slots,
                           "max_len": eng.max_len,
                           "page_size": eng.page_size,
                           "prefill_chunk": eng.prefill_chunk,
                           "prompt_lens": plens},
            }, f, indent=2)


if __name__ == "__main__":
    import sys

    def _emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")

    analysis_bench(_emit, json_path=(sys.argv[1] if len(sys.argv) > 1
                                     else "BENCH_PR9.json"))
