"""PR 3 bench: paged-KV serving engine on a mixed-length request trace.

Emits ``bench.serve.*`` CSV rows and writes ``BENCH_PR3.json`` (uploaded
as a CI artifact) with three sections:

  * ``throughput`` — decoded tokens/s and mean/max time-to-first-token
    over a mixed-length synthetic trace on the reduced deepseek config.
  * ``kv_traffic`` — modeled KV HBM bytes over the engine's recorded
    decode trace: live-page gathers vs the seed's dense
    ``n_slots x max_len`` lockstep caches (``core/block_traffic.py``).
    The ratio is geometry-independent, so the smoke-model trace prices
    the full-size arch too.
  * ``compiles``   — compiled-program counts of the two serving entry
    points (prefill buckets + the single decode step program).
"""
from __future__ import annotations

import json
import statistics
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import REDUCED
from repro.core.block_traffic import serve_kv_traffic
from repro.core.types import PagingConfig
from repro.models import lm
from repro.serve.engine import Engine, Request

# mixed prompt lengths, mean ~18 tokens against max_len=128: the regime
# the ISSUE's acceptance criterion prices (mean <= max_len / 4)
PROMPT_LENS = [5, 9, 17, 33, 12, 47, 7, 24, 14, 40, 6, 20]


def serve_bench(emit, json_path=None, *, n_slots: int = 4,
                max_len: int = 128, page_size: int = 16,
                max_new: int = 16):
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    eng = Engine(params, cfg, n_slots=n_slots, max_len=max_len,
                 eos_id=-1, paging=PagingConfig(page_size=page_size))
    # warm-up: one request per bucket the trace touches + a decode step,
    # so the timed run measures serving, not XLA compilation
    from repro.serve.paging import bucket_for
    warm = sorted({bucket_for(p, eng.buckets) for p in PROMPT_LENS})
    for i, plen in enumerate(min(b, max_len - 2) for b in warm):
        eng.submit(Request(rid=-1 - i, prompt=jnp.zeros((plen,),
                                                        jnp.int32),
                           max_new=2))
    eng.run()
    eng.completed.clear()
    for i, plen in enumerate(PROMPT_LENS):
        prompt = jax.random.randint(jax.random.fold_in(key, i), (plen,),
                                    0, cfg.vocab)
        eng.submit(Request(rid=i, prompt=prompt, max_new=max_new))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0

    total_new = sum(len(c.tokens) for c in done)
    ttfts = [c.ttft_s for c in done]
    throughput = {
        "requests": len(done),
        "decoded_tokens": total_new,
        "tokens_per_s": total_new / dt,
        "ttft_ms_mean": statistics.mean(ttfts) * 1e3,
        "ttft_ms_max": max(ttfts) * 1e3,
        "wall_s": dt,
    }
    traffic = serve_kv_traffic(eng.kv_trace, cfg, n_slots=n_slots,
                               max_len=max_len, page_size=eng.page_size)
    compiles = eng.compile_counts()
    compiles["buckets"] = eng.buckets

    emit("bench.serve.tokens_per_s", dt / max(total_new, 1) * 1e6,
         f"{throughput['tokens_per_s']:.1f} tok/s over {len(done)} reqs")
    emit("bench.serve.ttft", throughput["ttft_ms_mean"] * 1e3,
         f"mean {throughput['ttft_ms_mean']:.1f}ms "
         f"max {throughput['ttft_ms_max']:.1f}ms")
    emit("bench.serve.kv_bytes", 0,
         f"paged={traffic['paged_bytes']} dense={traffic['dense_bytes']} "
         f"ratio={traffic['ratio']:.2f}")
    emit("bench.serve.compiles", 0,
         f"prefill={compiles['prefill']} step={compiles['step']} "
         f"buckets={len(eng.buckets or [])}")

    result = {"throughput": throughput, "kv_traffic": traffic,
              "compiles": compiles,
              "config": {"arch": cfg.name, "n_slots": n_slots,
                         "max_len": max_len, "page_size": eng.page_size,
                         "prompt_lens": PROMPT_LENS,
                         "max_new": max_new}}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main():
    json_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_PR3.json"

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")

    serve_bench(emit, json_path=json_path)
    print(f"wrote {json_path}")


if __name__ == "__main__":
    main()
