"""PR 3 + PR 5 + PR 7 + PR 8 serving benches: paged-KV engine traces.

``prefix_cache_bench`` (PR 8) prices the radix-tree prefix cache on a
shared-system-prompt trace (the production regime: many users, a
handful of system prompts). Writes ``BENCH_PR8.json`` — prefix hit
rate, modeled prefill-FLOPs saved, peak live-page reduction, and p95
TTFT hit vs miss with the queue-wait / compute split — and asserts
greedy parity cache-on vs cache-off plus the compile bound.

``preemption_bench`` (PR 7) prices fault-tolerant scheduling: a pool
sized below the trace's worst-case demand forces pool-pressure
preemption (youngest slot evicted, pages rolled back, request requeued
with its produced tokens). Writes ``BENCH_PR7.json`` — goodput
(ok-completions/s) vs an unpressured reference pool, preemption count,
and recompute overhead tokens.

``serve_bench`` (PR 3) emits ``bench.serve.*`` CSV rows and writes
``BENCH_PR3.json`` (uploaded as a CI artifact) with three sections:

  * ``throughput`` — decoded tokens/s and mean/max time-to-first-token
    over a mixed-length synthetic trace on the reduced deepseek config.
  * ``kv_traffic`` — modeled KV HBM bytes over the engine's recorded
    decode trace: live-page gathers vs the seed's dense
    ``n_slots x max_len`` lockstep caches (``core/block_traffic.py``).
    The ratio is geometry-independent, so the smoke-model trace prices
    the full-size arch too.
  * ``compiles``   — compiled-program counts of the serving entry
    points (prefill buckets + chunk shapes + the decode step program).

``chunked_prefill_bench`` (PR 5) measures the TTFT cliff: a max-bucket
prompt is admitted ahead of short co-resident requests, with chunked
prefill off and on. Off, the shorts' first tokens (and the decode
slots' inter-token cadence) wait behind one monolithic largest-bucket
program; on, the prompt prefills as bounded row panels interleaved with
decode steps. Writes ``BENCH_PR5.json`` with measured p50/p95 TTFT and
inter-token latency both ways plus the modeled stall/re-read trade
(``core/block_traffic.chunked_prefill_traffic``), and *asserts* the
acceptance criterion — p95 TTFT of the co-resident shorts strictly
improves with chunking on.
"""
from __future__ import annotations

import json
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REDUCED
from repro.core.block_traffic import (chunked_prefill_traffic_cfg,
                                      prefix_cache_traffic,
                                      serve_kv_traffic)
from repro.core.types import PagingConfig
from repro.models import lm
from repro.serve import placement as placement_mod
from repro.serve.engine import Engine, Request

# mixed prompt lengths, mean ~18 tokens against max_len=128: the regime
# the ISSUE's acceptance criterion prices (mean <= max_len / 4)
PROMPT_LENS = [5, 9, 17, 33, 12, 47, 7, 24, 14, 40, 6, 20]


def serve_bench(emit, json_path=None, *, n_slots: int = 4,
                max_len: int = 128, page_size: int = 16,
                max_new: int = 16, mesh_shape: str = ""):
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    eng = Engine(params, cfg, n_slots=n_slots, max_len=max_len,
                 eos_id=-1, paging=PagingConfig(page_size=page_size),
                 placement=placement_mod.from_mesh_shape(mesh_shape))
    # warm-up: one request per bucket the trace touches + a decode step,
    # so the timed run measures serving, not XLA compilation
    from repro.serve.paging import bucket_for
    warm = sorted({bucket_for(p, eng.buckets) for p in PROMPT_LENS})
    for i, plen in enumerate(min(b, max_len - 2) for b in warm):
        eng.submit(Request(rid=-1 - i, prompt=jnp.zeros((plen,),
                                                        jnp.int32),
                           max_new=2))
    eng.run()
    eng.completed.clear()
    for i, plen in enumerate(PROMPT_LENS):
        prompt = jax.random.randint(jax.random.fold_in(key, i), (plen,),
                                    0, cfg.vocab)
        eng.submit(Request(rid=i, prompt=prompt, max_new=max_new))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0

    total_new = sum(len(c.tokens) for c in done)
    ttfts = [c.ttft_s for c in done]
    # TTFT split (PR 8 reporting fix): queue wait (submission -> first
    # admission) vs compute (admission -> first token), so a cache-hit
    # trace can attribute its TTFT win to skipped prefill rather than a
    # shorter queue
    queues = [c.queue_s for c in done]
    computes = [c.ttft_s - c.queue_s for c in done]
    throughput = {
        "requests": len(done),
        "decoded_tokens": total_new,
        "tokens_per_s": total_new / dt,
        "ttft_ms_mean": statistics.mean(ttfts) * 1e3,
        "ttft_ms_max": max(ttfts) * 1e3,
        "queue_ms_mean": statistics.mean(queues) * 1e3,
        "compute_ttft_ms_mean": statistics.mean(computes) * 1e3,
        "wall_s": dt,
    }
    traffic = serve_kv_traffic(eng.kv_trace, cfg, n_slots=n_slots,
                               max_len=max_len, page_size=eng.page_size)
    compiles = eng.compile_counts()
    compiles["buckets"] = eng.buckets

    emit("bench.serve.tokens_per_s", dt / max(total_new, 1) * 1e6,
         f"{throughput['tokens_per_s']:.1f} tok/s over {len(done)} reqs")
    emit("bench.serve.ttft", throughput["ttft_ms_mean"] * 1e3,
         f"mean {throughput['ttft_ms_mean']:.1f}ms "
         f"max {throughput['ttft_ms_max']:.1f}ms")
    emit("bench.serve.kv_bytes", 0,
         f"paged={traffic['paged_bytes']} dense={traffic['dense_bytes']} "
         f"ratio={traffic['ratio']:.2f}")
    emit("bench.serve.compiles", 0,
         f"prefill={compiles['prefill']} step={compiles['step']} "
         f"buckets={len(eng.buckets or [])}")

    result = {"throughput": throughput, "kv_traffic": traffic,
              "compiles": compiles,
              "config": {"arch": cfg.name, "n_slots": n_slots,
                         "max_len": max_len, "page_size": eng.page_size,
                         "prompt_lens": PROMPT_LENS,
                         "max_new": max_new}}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def chunked_prefill_bench(emit, json_path=None, *, n_slots: int = 4,
                          max_len: int = 128, page_size: int = 16,
                          chunk: int = 32, n_shorts: int = 3,
                          short_len: int = 8, short_new: int = 16):
    """TTFT-cliff A/B: one near-max-bucket prompt admitted ahead of
    ``n_shorts`` short co-resident requests, chunked prefill off vs on."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    big_len = max_len - 8                 # pads to the max bucket;
    #                                       leaves room to decode

    def drive(chunk_size):
        eng = Engine(params, cfg, n_slots=n_slots, max_len=max_len,
                     eos_id=-1,
                     paging=PagingConfig(page_size=page_size,
                                         prefill_chunk=chunk_size))
        prompts = {-1: jnp.zeros((big_len,), jnp.int32)}
        for i in range(n_shorts):
            prompts[i] = jax.random.randint(
                jax.random.fold_in(key, i), (short_len,), 0, cfg.vocab)

        def submit_all(tag):
            # the cliff scenario: the big prompt is at the queue head,
            # shorts land co-resident right behind it
            eng.submit(Request(rid=tag * 100 - 1, prompt=prompts[-1],
                               max_new=2))
            for i in range(n_shorts):
                eng.submit(Request(rid=tag * 100 + i, prompt=prompts[i],
                                   max_new=short_new))

        submit_all(0)                     # warm-up: compile every program
        eng.run()
        eng.completed.clear()
        submit_all(1)
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0

        shorts = [c for c in done if c.rid >= 100]
        big = next(c for c in done if c.rid == 99)
        ttfts = np.asarray([c.ttft_s for c in shorts]) * 1e3
        itls = np.asarray([g for c in shorts for g in c.itl_s]) * 1e3
        counts = eng.compile_counts()
        n_chunk_shapes = len([b for b in eng.buckets
                              if b <= eng.prefill_chunk])
        assert (counts["prefill"] + counts["chunk"] + counts["step"]
                <= len(eng.buckets) + n_chunk_shapes + 1), counts
        return {
            "short_ttft_ms_p50": float(np.percentile(ttfts, 50)),
            "short_ttft_ms_p95": float(np.percentile(ttfts, 95)),
            "short_itl_ms_p50": float(np.percentile(itls, 50)),
            "short_itl_ms_p95": float(np.percentile(itls, 95)),
            "big_ttft_ms": big.ttft_s * 1e3,
            "wall_s": wall,
            "compiles": counts,
        }

    off = drive(0)
    on = drive(chunk)
    improves = on["short_ttft_ms_p95"] < off["short_ttft_ms_p95"]
    modeled = chunked_prefill_traffic_cfg(cfg, big_len, chunk_size=chunk,
                                          page_size=page_size)
    emit("bench.serve.chunked.ttft_p95",
         on["short_ttft_ms_p95"] * 1e3,
         f"co-resident p95 TTFT {off['short_ttft_ms_p95']:.1f}ms -> "
         f"{on['short_ttft_ms_p95']:.1f}ms (chunk={chunk})")
    emit("bench.serve.chunked.itl_p95", on["short_itl_ms_p95"] * 1e3,
         f"co-resident p95 ITL {off['short_itl_ms_p95']:.1f}ms -> "
         f"{on['short_itl_ms_p95']:.1f}ms")
    emit("bench.serve.chunked.stall", 0,
         f"stall rows {modeled['stall_rows_one_shot']} -> "
         f"{modeled['stall_rows_chunked']}; prefix reread "
         f"{modeled['prefix_reread_bytes']}B over "
         f"{modeled['n_chunks']} chunks")

    result = {"off": off, "on": on,
              "p95_ttft_improves": bool(improves),
              "modeled": modeled,
              "config": {"arch": cfg.name, "n_slots": n_slots,
                         "max_len": max_len, "page_size": page_size,
                         "prefill_chunk": chunk, "big_len": big_len,
                         "n_shorts": n_shorts, "short_len": short_len,
                         "short_new": short_new}}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
    # acceptance (ISSUE 5): admitting a max-bucket prompt must no longer
    # cliff the co-resident decode slots' first tokens
    assert improves, (
        "chunked prefill did not improve co-resident p95 TTFT: "
        f"off={off['short_ttft_ms_p95']:.2f}ms "
        f"on={on['short_ttft_ms_p95']:.2f}ms")
    return result


def preemption_bench(emit, json_path=None, *, n_slots: int = 4,
                     max_len: int = 128, page_size: int = 16,
                     n_requests: int = 6, prompt_len: int = 32,
                     max_new: int = 16, n_pages: int = 0,
                     patience: int = 3):
    """PR 7: goodput under preemption pressure. The pool is sized below
    the trace's worst-case demand (default: half of what ``n_requests``
    want at once), so the queue head starves behind live residents and
    pool-pressure preemption (``preempt_patience``) must evict the
    youngest slot — pages roll back, the victim re-enqueues with its
    produced tokens and recomputes through the ordinary prefill path.
    Reports goodput (ok-completions/s), the preemption count and the
    recompute overhead in tokens; asserts at least one preemption fired
    and every request still finished ``ok``."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    worst = min(max_len, prompt_len + max_new - 1)
    pages_per_req = -(-worst // page_size)
    n_pages = n_pages or 2 * pages_per_req      # two residents at a time
    prompts = [jax.random.randint(jax.random.fold_in(key, i),
                                  (prompt_len,), 0, cfg.vocab)
               for i in range(n_requests)]

    def drive(pool_pages, pat):
        eng = Engine(params, cfg, n_slots=n_slots, max_len=max_len,
                     eos_id=-1,
                     paging=PagingConfig(page_size=page_size,
                                         n_pages=pool_pages),
                     preempt_patience=pat)
        # warm-up on the single bucket + decode program
        eng.submit(Request(rid=-1, prompt=prompts[0], max_new=2))
        eng.run()
        eng.completed.clear()
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new=max_new))
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        ok = [c for c in done if c.status in ("ok", "eos", "length")]
        return eng, done, wall, ok

    eng, done, wall, ok = drive(n_pages, patience)
    assert eng.stats["preemptions"] >= 1, (
        "preemption pressure trace fired no preemptions: "
        f"pool={n_pages} pages, stats={eng.stats}")
    assert len(ok) == n_requests, [(c.rid, c.status) for c in done]
    # reference: the same trace on a full-occupancy pool (no pressure)
    ref_eng, _, ref_wall, ref_ok = drive(0, None)
    assert ref_eng.stats["preemptions"] == 0

    decoded = sum(len(c.tokens) for c in ok)
    result = {
        "goodput": {"ok_completions_per_s": len(ok) / wall,
                    "ok_completions": len(ok),
                    "decoded_tokens": decoded, "wall_s": wall,
                    "reference_ok_per_s": len(ref_ok) / ref_wall,
                    "reference_wall_s": ref_wall},
        "preemptions": eng.stats["preemptions"],
        "recompute": {"tokens": eng.stats["recompute_tokens"],
                      "overhead_per_decoded":
                          eng.stats["recompute_tokens"] / max(decoded, 1)},
        "statuses": {s: sum(1 for c in done if c.status == s)
                     for s in {c.status for c in done}},
        "config": {"arch": cfg.name, "n_slots": n_slots,
                   "max_len": max_len, "page_size": page_size,
                   "n_pages": n_pages, "n_requests": n_requests,
                   "prompt_len": prompt_len, "max_new": max_new,
                   "preempt_patience": patience},
    }
    emit("bench.serve.preempt.goodput", wall / max(len(ok), 1) * 1e6,
         f"{result['goodput']['ok_completions_per_s']:.2f} ok/s under "
         f"pressure vs {result['goodput']['reference_ok_per_s']:.2f} "
         f"unpressured ({n_pages} vs full pool pages)")
    emit("bench.serve.preempt.recompute", 0,
         f"{eng.stats['preemptions']} preemptions, "
         f"{eng.stats['recompute_tokens']} recomputed tokens "
         f"({result['recompute']['overhead_per_decoded']:.2f} per "
         "decoded)")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def prefix_cache_bench(emit, json_path=None, *, n_slots: int = 4,
                       max_len: int = 128, page_size: int = 16,
                       chunk: int = 32, n_sys: int = 2,
                       sys_len: int = 64, n_requests: int = 12,
                       tail_len: int = 8, max_new: int = 8):
    """PR 8: the shared-system-prompt trace. ``n_requests`` prompts are
    ``n_sys`` system prompts of ``sys_len`` tokens plus a unique
    ``tail_len``-token user turn; one warm-up request per system prompt
    seeds the radix tree (and compiles every chunk shape), then the
    timed trace runs with the prefix cache on and off.

    Asserts the ISSUE acceptance criteria: greedy parity on vs off,
    >= 80% prefix hit rate, >= 5x modeled prefill-FLOPs reduction,
    a strict peak-unique-live-page reduction, and the
    ``n_buckets + n_chunk_shapes + 1`` compile bound unchanged."""
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    sys_prompts = [rng.integers(2, cfg.vocab - 2, sys_len)
                   for _ in range(n_sys)]
    prompts = [np.concatenate(
        [sys_prompts[i % n_sys],
         rng.integers(2, cfg.vocab - 2, tail_len)]).astype(np.int32)
        for i in range(n_requests)]

    def drive(prefix_on):
        eng = Engine(params, cfg, n_slots=n_slots, max_len=max_len,
                     eos_id=-1,
                     paging=PagingConfig(page_size=page_size,
                                         prefill_chunk=chunk,
                                         prefix_cache=prefix_on))
        # warm-up: one request per system prompt — seeds the tree (on
        # the cached run) and compiles every chunk shape + decode
        for i, sp in enumerate(sys_prompts):
            warm = np.concatenate(
                [sp, rng.integers(2, cfg.vocab - 2, tail_len)]
            ).astype(np.int32)
            eng.submit(Request(rid=-1 - i, prompt=jnp.asarray(warm),
                               max_new=2))
        eng.run()
        eng.completed.clear()
        base = dict(eng.stats)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=jnp.asarray(p),
                               max_new=max_new))
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        delta = {k: eng.stats[k] - base[k] for k in eng.stats}
        counts = eng.compile_counts()
        n_chunk_shapes = len([b for b in eng.buckets
                              if b <= eng.prefill_chunk])
        assert (counts["prefill"] + counts["chunk"] + counts["step"]
                <= len(eng.buckets) + n_chunk_shapes + 1), counts
        eng.pool.check_conservation()
        return eng, done, wall, delta, counts

    eng_off, done_off, wall_off, _, counts_off = drive(False)
    eng_on, done_on, wall_on, delta, counts_on = drive(True)

    streams_off = {c.rid: list(c.tokens) for c in done_off}
    streams_on = {c.rid: list(c.tokens) for c in done_on}
    parity = streams_off == streams_on
    assert parity, "prefix cache changed a greedy stream"

    # hit rate + modeled FLOPs from the timed-trace stat deltas
    assert delta["prefix_hits"] == n_requests, delta
    plen = sys_len + tail_len
    hit_per_req = delta["prefix_hit_tokens"] // n_requests
    traffic = prefix_cache_traffic(
        cfg, [(plen, hit_per_req)] * n_requests, page_size=page_size)
    assert traffic["hit_rate"] >= 0.8, traffic
    assert traffic["flops_ratio"] >= 5.0, traffic

    # live pages: peak distinct physical pages over the timed trace
    peak_on = max(u for u, _ in eng_on.page_trace)
    peak_off = max(u for u, _ in eng_off.page_trace)
    assert peak_on < peak_off, (peak_on, peak_off)

    def pct(xs, q):
        return float(np.percentile(np.asarray(xs) * 1e3, q))

    ttft = {
        "hit_ttft_ms_p95": pct([c.ttft_s for c in done_on], 95),
        "miss_ttft_ms_p95": pct([c.ttft_s for c in done_off], 95),
        "hit_queue_ms_p95": pct([c.queue_s for c in done_on], 95),
        "miss_queue_ms_p95": pct([c.queue_s for c in done_off], 95),
        "hit_compute_ttft_ms_p95": pct(
            [c.ttft_s - c.queue_s for c in done_on], 95),
        "miss_compute_ttft_ms_p95": pct(
            [c.ttft_s - c.queue_s for c in done_off], 95),
    }

    emit("bench.serve.prefix.hit_rate", 0,
         f"{traffic['hit_rate']:.3f} over {n_requests} reqs "
         f"({delta['prefix_hit_tokens']}/{delta['prompt_tokens']} tokens)")
    emit("bench.serve.prefix.flops", 0,
         f"prefill FLOPs {traffic['flops_cold']} -> "
         f"{traffic['flops_actual']} ({traffic['flops_ratio']:.1f}x)")
    emit("bench.serve.prefix.live_pages", 0,
         f"peak unique {peak_off} -> {peak_on} pages")
    emit("bench.serve.prefix.ttft", ttft["hit_ttft_ms_p95"] * 1e3,
         f"p95 TTFT hit {ttft['hit_ttft_ms_p95']:.1f}ms vs miss "
         f"{ttft['miss_ttft_ms_p95']:.1f}ms (compute "
         f"{ttft['hit_compute_ttft_ms_p95']:.1f} vs "
         f"{ttft['miss_compute_ttft_ms_p95']:.1f})")

    result = {
        "hit_rate": {"rate": traffic["hit_rate"],
                     "hits": delta["prefix_hits"],
                     "hit_tokens": delta["prefix_hit_tokens"],
                     "prompt_tokens": delta["prompt_tokens"],
                     "cow_copies": delta["cow_copies"],
                     "cow_in_place": delta["cow_in_place"],
                     "share_deferrals": delta["share_deferrals"]},
        "flops": {k: traffic[k] for k in
                  ("flops_cold", "flops_actual", "flops_saved",
                   "flops_ratio", "hit_kv_bytes")},
        "live_pages": {"peak_unique_on": peak_on,
                       "peak_unique_off": peak_off,
                       "ratio": peak_off / peak_on},
        "ttft": ttft,
        "parity": parity,
        "compiles": {"on": counts_on, "off": counts_off,
                     "buckets": eng_on.buckets},
        "config": {"arch": cfg.name, "n_slots": n_slots,
                   "max_len": max_len, "page_size": page_size,
                   "prefill_chunk": chunk, "n_sys": n_sys,
                   "sys_len": sys_len, "n_requests": n_requests,
                   "tail_len": tail_len, "max_new": max_new,
                   "wall_s_on": wall_on, "wall_s_off": wall_off},
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main():
    json_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_PR3.json"
    json_path5 = sys.argv[2] if len(sys.argv) > 2 else "BENCH_PR5.json"
    json_path7 = sys.argv[3] if len(sys.argv) > 3 else "BENCH_PR7.json"
    json_path8 = sys.argv[4] if len(sys.argv) > 4 else "BENCH_PR8.json"

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")

    serve_bench(emit, json_path=json_path)
    print(f"wrote {json_path}")
    chunked_prefill_bench(emit, json_path=json_path5)
    print(f"wrote {json_path5}")
    preemption_bench(emit, json_path=json_path7)
    print(f"wrote {json_path7}")
    prefix_cache_bench(emit, json_path=json_path8)
    print(f"wrote {json_path8}")


if __name__ == "__main__":
    main()
