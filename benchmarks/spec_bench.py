"""PR 10 bench: self-speculative decoding through the paged path.

A repetitive smoke trace (looped n-gram prompts — the regime prompt
lookup targets: extraction, code edits, templated chat) runs spec-off
and spec-on. Writes ``BENCH_PR10.json`` with:

  * ``acceptance`` — accepted-tokens-per-step (emitted tokens per live
    slot per speculative step) and the draft acceptance rate; asserts
    the ISSUE criterion ``tokens_per_step > 1.3``.
  * ``traffic`` — ``core/block_traffic.spec_step_traffic`` bytes model
    over the engine's recorded trace: bytes per *accepted* token vs
    plain decode's bytes per token (weight streaming + prefix gather
    amortized over ``1 + n_acc`` emissions).
  * ``parity`` — greedy streams spec-on vs spec-off compared as
    ``{rid: tokens}`` dicts; asserted bit-identical.
  * ``compiles`` — verify-panel program count, asserted within the
    documented k-ladder (``len(spec_ladder(K))``).
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REDUCED
from repro.core.block_traffic import spec_step_traffic
from repro.core.types import PagingConfig
from repro.models import lm
from repro.serve.engine import Engine, Request
from repro.serve.paging import spec_ladder


def _repetitive_prompts(rng, n, vocab, base_len=10, period=5):
    """Looped-phrase prompts: a short random phrase repeated to
    ``base_len``+ tokens, so the trailing n-gram always has an earlier
    match and the drafter's proposal is usually right."""
    prompts = []
    for _ in range(n):
        phrase = rng.integers(2, vocab - 2, period)
        reps = -(-base_len // period) + 1
        prompts.append(np.tile(phrase, reps).astype(np.int32))
    return prompts


def spec_bench(emit, json_path=None, *, n_slots: int = 4,
               max_len: int = 128, page_size: int = 16,
               speculate_k: int = 4, n_requests: int = 6,
               max_new: int = 48):
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    prompts = _repetitive_prompts(rng, n_requests, cfg.vocab)

    def drive(k):
        eng = Engine(params, cfg, n_slots=n_slots, max_len=max_len,
                     eos_id=-1,
                     paging=PagingConfig(page_size=page_size,
                                         speculate_k=k))
        # warm-up: compile the prefill bucket, decode and (spec-on) the
        # reachable verify panels, so the timed run measures serving
        eng.submit(Request(rid=-1, prompt=jnp.asarray(prompts[0]),
                           max_new=4))
        eng.run()
        eng.completed.clear()
        base = dict(eng.stats)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=jnp.asarray(p),
                               max_new=max_new))
        t0 = time.perf_counter()
        done = eng.run()
        wall = time.perf_counter() - t0
        delta = {k2: eng.stats[k2] - base[k2] for k2 in eng.stats}
        eng.pool.check_conservation()
        return eng, done, wall, delta

    eng_off, done_off, wall_off, _ = drive(0)
    eng_on, done_on, wall_on, delta = drive(speculate_k)

    # parity: greedy streams must be bit-identical with the drafter on
    streams_off = {c.rid: list(c.tokens) for c in done_off}
    streams_on = {c.rid: list(c.tokens) for c in done_on}
    parity = streams_off == streams_on
    assert parity, "speculation changed a greedy stream"

    # acceptance: emitted tokens per live slot per speculative step
    slot_steps = delta["spec_slot_steps"]
    accepted = delta["spec_accepted"]
    tokens_per_step = (slot_steps + accepted) / max(slot_steps, 1)
    accept_rate = accepted / max(delta["spec_drafted"], 1)
    assert tokens_per_step > 1.3, (
        "repetitive trace accepted too little: "
        f"{tokens_per_step:.2f} tokens/step over {slot_steps} "
        f"slot-steps ({accepted} accepted / {delta['spec_drafted']} "
        "drafted)")

    # compile bound: verify panels stay within the documented k-ladder
    counts = eng_on.compile_counts()
    ladder = spec_ladder(speculate_k)
    assert counts["spec"] <= len(ladder), (counts, ladder)
    assert eng_off.compile_counts().get("spec", 0) == 0

    # traffic: one verify step at the trace's busiest row vs decoding
    # the same emissions one at a time
    lengths = max(eng_on.kv_trace, key=len) if eng_on.kv_trace \
        else [max_len // 2]
    mean_acc = accepted / max(slot_steps, 1)
    traffic = spec_step_traffic(
        cfg, lengths=lengths,
        accepted_total=int(round(mean_acc * len(lengths))),
        page_size=page_size, n_slots=n_slots)

    emit("bench.serve.spec.accept", 0,
         f"{tokens_per_step:.2f} tokens/slot-step "
         f"(rate {accept_rate:.2f} over {delta['spec_drafted']} drafted)")
    emit("bench.serve.spec.traffic", 0,
         f"{traffic['bytes_per_accepted']:.0f} B/accepted vs "
         f"{traffic['decode_bytes_per_token']:.0f} B/token plain "
         f"(x{traffic['amortization']:.2f})")
    emit("bench.serve.spec.compiles", 0,
         f"spec={counts['spec']} ladder={ladder} "
         f"(+{counts['prefill']} prefill +{counts['step']} step)")

    result = {
        "acceptance": {"tokens_per_step": tokens_per_step,
                       "accept_rate": accept_rate,
                       "accepted": accepted,
                       "drafted": delta["spec_drafted"],
                       "spec_steps": delta["spec_steps"],
                       "slot_steps": slot_steps},
        "traffic": traffic,
        "parity": parity,
        "compiles": {"on": counts, "off": eng_off.compile_counts(),
                     "spec_ladder": ladder},
        "config": {"arch": cfg.name, "n_slots": n_slots,
                   "max_len": max_len, "page_size": page_size,
                   "speculate_k": speculate_k,
                   "n_requests": n_requests, "max_new": max_new,
                   "wall_s_on": wall_on, "wall_s_off": wall_off},
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main():
    json_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_PR10.json"

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")

    spec_bench(emit, json_path=json_path)
    print(f"wrote {json_path}")


if __name__ == "__main__":
    main()
