"""PR 4 bench: decode-step projection-weight traffic, pre-fused param
layout vs the PR 2 per-call concat regime.

Emits ``bench.decode.*`` CSV rows and writes ``BENCH_PR4.json``
(uploaded as a CI artifact) with three sections:

  * ``weight_traffic`` — modeled HBM bytes for one attn+MLP block
    decode step at M = n_slots rows (``core/block_traffic.py``), for
    the smoke geometry AND the full-size deepseek-7b geometry: the
    pre-fused layout streams the stored wqkv / wgi panels once, the
    per-call regime additionally read the split parts and wrote the
    concatenated panel every step.
  * ``jaxpr``          — audit of the traced decode step (dense and
    paged): number of weight-sized concatenates left. Must be 0 — the
    acceptance criterion the tests also assert.
  * ``wall_us``        — measured wall time of one jitted decode step
    (n_slots rows, paged cache) on this host's default impl.
"""
from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.analysis import min_weight_bytes, weight_concat_eqns
from repro.configs import REDUCED
from repro.configs.deepseek_7b import CONFIG as DEEPSEEK_FULL
from repro.core.block_traffic import decode_weight_traffic_cfg
from repro.models import lm

N_SLOTS = 4


def _traffic_section():
    out = {}
    for name, cfg in (("deepseek_smoke", REDUCED["deepseek-7b"]()),
                      ("deepseek_7b", DEEPSEEK_FULL)):
        fused = decode_weight_traffic_cfg(cfg, n_slots=N_SLOTS,
                                          prefused=True)
        percall = decode_weight_traffic_cfg(cfg, n_slots=N_SLOTS,
                                            prefused=False)
        out[name] = {
            "prefused_weight_bytes": fused["weight_bytes"],
            "percall_weight_bytes": percall["weight_bytes"],
            "weight_ratio": percall["weight_bytes"] / fused["weight_bytes"],
            "prefused_total": fused["total"],
            "percall_total": percall["total"],
            "total_ratio": percall["total"] / fused["total"],
            "prefused_ops": [(n, t, w) for n, t, w in fused["ops"]],
            "percall_ops": [(n, t, w) for n, t, w in percall["ops"]],
        }
    return out


def _jaxpr_section():
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    tok = jnp.zeros((N_SLOTS, 1), jnp.int32)
    lengths = jnp.full((N_SLOTS,), 3, jnp.int32)
    thr = min_weight_bytes(cfg)

    dense_cache = lm.init_cache(cfg, N_SLOTS, 32, jnp.float32)
    dense = jax.make_jaxpr(
        lambda p, c, t, ln: lm.decode_step(p, c, t, ln, cfg))(
            params, dense_cache, tok, lengths)

    paged_cache = lm.init_paged_cache(cfg, N_SLOTS, 32, page_size=8)
    tables = jnp.zeros((N_SLOTS, 4), jnp.int32)
    paged = jax.make_jaxpr(
        lambda p, c, t, ln, tb: lm.decode_step(p, c, t, ln, cfg,
                                               pages=tb))(
            params, paged_cache, tok, lengths, tables)

    return {"threshold_bytes": thr,
            "dense_weight_concats": len(weight_concat_eqns(dense, thr)),
            "paged_weight_concats": len(weight_concat_eqns(paged, thr))}


def _wall_us(iters: int = 10):
    cfg = REDUCED["deepseek-7b"]()
    key = jax.random.PRNGKey(0)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    cache = lm.init_paged_cache(cfg, N_SLOTS, 32, page_size=8,
                                dtype=jnp.float32)
    tables = jnp.tile(jnp.arange(4, dtype=jnp.int32)[None], (N_SLOTS, 1))
    tok = jnp.zeros((N_SLOTS, 1), jnp.int32)
    lengths = jnp.full((N_SLOTS,), 3, jnp.int32)

    step = jax.jit(lambda p, c, t, ln, tb: lm.decode_step(p, c, t, ln,
                                                          cfg, pages=tb))
    out = jax.block_until_ready(step(params, cache, tok, lengths, tables))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(step(params, cache, tok, lengths,
                                         tables))
    del out
    return (time.perf_counter() - t0) / iters * 1e6


def decode_bench(emit, json_path=None):
    traffic = _traffic_section()
    for name, row in traffic.items():
        emit(f"bench.decode.weights_{name}", 0,
             f"prefused={row['prefused_weight_bytes']} "
             f"percall={row['percall_weight_bytes']} "
             f"ratio={row['weight_ratio']:.2f}")
    jx = _jaxpr_section()
    emit("bench.decode.weight_concats", 0,
         f"dense={jx['dense_weight_concats']} "
         f"paged={jx['paged_weight_concats']} (must be 0)")
    wall = _wall_us()
    emit("bench.decode.step_wall", wall, f"{N_SLOTS}-slot paged step us")
    result = {"weight_traffic": traffic, "jaxpr": jx,
              "wall_us": {"paged_step": wall, "n_slots": N_SLOTS}}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main():
    json_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_PR4.json"

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")

    decode_bench(emit, json_path=json_path)
    print(f"wrote {json_path}")


if __name__ == "__main__":
    main()
