"""Aggregate dry-run artifacts into the §Roofline table."""
from __future__ import annotations

import glob
import json
import os


def load_cells(dryrun_dir: str):
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        tag = os.path.basename(path)[:-5]
        parts = tag.split("__")
        d["_tag"] = tag
        if len(parts) == 3:
            d.setdefault("arch", parts[0])
            d.setdefault("shape", parts[1])
            d.setdefault("mesh", parts[2])
        cells.append(d)
    return cells


def markdown_table(cells, mesh_filter: str = "pod") -> str:
    rows = ["| arch | shape | compute (ms) | memory raw/fused (ms) | "
            "collective (ms) | bound | useful | MFU | live GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for d in cells:
        if d.get("skipped"):
            rows.append(f"| {d.get('arch','?')} | {d.get('shape','?')} | "
                        f"SKIP ({d.get('reason','')}) | | | | | | |")
            continue
        if not d.get("ok") or mesh_filter not in str(d.get("mesh", "")):
            continue
        rows.append(
            f"| {d['arch']} | {d['shape']} "
            f"| {d['compute_t']*1e3:.1f} "
            f"| {d['memory_t']*1e3:.1f}/{d['memory_t_fused']*1e3:.1f} "
            f"| {d['collective_t']*1e3:.1f} "
            f"| {d['bound']} "
            f"| {d['useful_flops_ratio']:.2f} "
            f"| {d['mfu']:.3f} "
            f"| {d['live_bytes_per_device']/1e9:.1f} |")
    return "\n".join(rows)


def roofline_report(emit, dryrun_dir: str = "experiments/dryrun"):
    cells = load_cells(dryrun_dir)
    ok = [c for c in cells if c.get("ok") and not c.get("skipped")]
    if not ok:
        emit("roofline.cells", 0, "no dry-run artifacts yet")
        return
    emit("roofline.cells_ok", 0, str(len(ok)))
    for d in ok:
        if "pod_16x16" not in str(d.get("mesh", "")):
            continue
        emit(f"roofline.{d['arch']}.{d['shape']}.mfu", 0,
             f"{d['mfu']:.3f}")
        emit(f"roofline.{d['arch']}.{d['shape']}.bound", 0, d["bound"])
