"""Microbenchmarks of the row-wise primitives on this host (XLA path;
the Pallas path targets TPU and is validated in interpret mode)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.rowwise import plan_matmul
from repro.kernels import ops


def _bench(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_suite(emit):
    key = jax.random.PRNGKey(0)
    cases = [("matmul_512", (512, 512, 512)),
             ("matmul_1k", (1024, 1024, 1024)),
             ("matmul_fc96", (3136, 96, 384))]
    for name, (m, k, n) in cases:
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(key, (k, n), jnp.float32)
        f = jax.jit(lambda a, b: ops.matmul(a, b, impl="ref"))
        us = _bench(f, x, w)
        emit(f"kernel.{name}", us,
             f"{2 * m * k * n / (us * 1e-6) / 1e9:.1f} GFLOP/s")

    q = jax.random.normal(key, (1, 8, 512, 64), jnp.float32)
    kk = jax.random.normal(key, (1, 2, 512, 64), jnp.float32)
    f = jax.jit(lambda a, b: ops.attention(a, b, b, causal=True,
                                           impl="ref"))
    us = _bench(f, q, kk)
    flops = 4 * 8 * 512 * 512 * 64 / 2
    emit("kernel.attention_512_gqa", us,
         f"{flops / (us * 1e-6) / 1e9:.1f} GFLOP/s")

    x = jax.random.normal(key, (4096, 1024), jnp.float32)
    g = jnp.ones((1024,), jnp.float32)
    f = jax.jit(lambda a, b: ops.layernorm(a, b, kind="rms", impl="ref"))
    us = _bench(f, x, g)
    emit("kernel.rmsnorm_4kx1k", us,
         f"{x.size * 4 * 2 / (us * 1e-6) / 1e9:.1f} GB/s")

    ksplit_sweep(emit)


def ksplit_sweep(emit, m=1024, n=1024):
    """Before/after HBM-traffic model for the fused in-VMEM adder tree.

    'before' is the seed's Python adder-tree loop: k_splits separate
    pallas_calls whose fp32 partials are written once per split and
    re-read (k_splits - 1) times — a (2*k_splits - 1) * M * N * 4 output
    term. 'after' is the fused k grid axis: partials never leave VMEM,
    outputs written exactly once. Timings use the XLA ref path (the
    Pallas path targets TPU; interpret mode is not a perf proxy).
    """
    key = jax.random.PRNGKey(1)
    for k in (1024, 4096, 16384, 65536):
        fp = plan_matmul(m, k, n, dtype_bytes=2)
        lp = plan_matmul(m, k, n, dtype_bytes=2, fused=False)
        out_rt = (2 * lp.k_splits - 2) * lp.m_pad * lp.n_pad * 4
        x = jax.random.normal(key, (m, k), jnp.bfloat16)
        w = jax.random.normal(key, (k, n), jnp.bfloat16)
        f = jax.jit(lambda a, b: ops.matmul(a, b, impl="ref"))
        us = _bench(f, x, w, iters=3)
        emit(f"kernel.ksplit_K{k}", us,
             f"splits={fp.k_splits} bytes_fused={fp.bytes_moved} "
             f"bytes_legacy={lp.bytes_moved} "
             f"saved={lp.bytes_moved - fp.bytes_moved} "
             f"out_roundtrip_removed={out_rt}")
