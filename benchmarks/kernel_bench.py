"""Microbenchmarks of the row-wise primitives on this host (XLA path;
the Pallas path targets TPU and is validated in interpret mode)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _bench(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_suite(emit):
    key = jax.random.PRNGKey(0)
    cases = [("matmul_512", (512, 512, 512)),
             ("matmul_1k", (1024, 1024, 1024)),
             ("matmul_fc96", (3136, 96, 384))]
    for name, (m, k, n) in cases:
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = jax.random.normal(key, (k, n), jnp.float32)
        f = jax.jit(lambda a, b: ops.matmul(a, b, impl="ref"))
        us = _bench(f, x, w)
        emit(f"kernel.{name}", us,
             f"{2 * m * k * n / (us * 1e-6) / 1e9:.1f} GFLOP/s")

    q = jax.random.normal(key, (1, 8, 512, 64), jnp.float32)
    kk = jax.random.normal(key, (1, 2, 512, 64), jnp.float32)
    f = jax.jit(lambda a, b: ops.attention(a, b, b, causal=True,
                                           impl="ref"))
    us = _bench(f, q, kk)
    flops = 4 * 8 * 512 * 512 * 64 / 2
    emit("kernel.attention_512_gqa", us,
         f"{flops / (us * 1e-6) / 1e9:.1f} GFLOP/s")

    x = jax.random.normal(key, (4096, 1024), jnp.float32)
    g = jnp.ones((1024,), jnp.float32)
    f = jax.jit(lambda a, b: ops.layernorm(a, b, kind="rms", impl="ref"))
    us = _bench(f, x, g)
    emit("kernel.rmsnorm_4kx1k", us,
         f"{x.size * 4 * 2 / (us * 1e-6) / 1e9:.1f} GB/s")
