"""PR 6 tensor-parallel serving bench: scaling + parity + traffic.

Runs the paged serving engine over emulated host meshes (the process
forces ``--xla_force_host_platform_device_count=8`` before importing
jax, so it must run in its own interpreter — ``benchmarks/run.py``
launches it as a subprocess) and writes ``BENCH_PR6.json``:

  * ``parity``  — greedy token streams at mesh sizes {1, 2, 4} checked
    bit-identical against the single-device engine over the mixed-
    length trace (chunked prefill mid-stream included);
  * ``scaling`` — wall time / tokens-per-s per mesh size. Emulated CPU
    "devices" share the same cores, so wall time does NOT drop with
    shards here — the number that transfers to real meshes is the
    modeled per-device traffic;
  * ``traffic`` — ``core.block_traffic.serve_tp_traffic`` over the
    recorded decode trace: per-device KV + weight bytes at tp=4 with
    the all-reduce term. Asserts the acceptance criterion — per-device
    bytes drop >= 3x vs single-device;
  * ``compiles`` — entry-point program counts per mesh size, asserted
    within the ``n_buckets + n_chunk_shapes + 1`` bound (the bound must
    survive sharding).
"""
from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import json                                              # noqa: E402
import sys                                               # noqa: E402
import time                                              # noqa: E402

import jax                                               # noqa: E402
import jax.numpy as jnp                                  # noqa: E402

from repro.configs import REDUCED                        # noqa: E402
from repro.core.block_traffic import serve_tp_traffic    # noqa: E402
from repro.core.types import PagingConfig                # noqa: E402
from repro.models import lm                              # noqa: E402
from repro.serve.engine import Engine, Request           # noqa: E402
from repro.serve.placement import (SingleDevice,         # noqa: E402
                                   TensorParallel)

PROMPT_LENS = [5, 9, 17, 33, 12, 47, 7, 24, 14, 40, 6, 20]
MESH_SIZES = (1, 2, 4)


def _drive(params, cfg, placement, *, n_slots, max_len, page_size,
           chunk, max_new):
    key = jax.random.PRNGKey(0)
    eng = Engine(params, cfg, n_slots=n_slots, max_len=max_len,
                 eos_id=-1,
                 paging=PagingConfig(page_size=page_size,
                                     prefill_chunk=chunk),
                 placement=placement)
    from repro.serve.paging import bucket_for
    warm = sorted({bucket_for(p, eng.buckets) for p in PROMPT_LENS})
    for i, plen in enumerate(min(b, max_len - 2) for b in warm):
        eng.submit(Request(rid=-1 - i,
                           prompt=jnp.zeros((plen,), jnp.int32),
                           max_new=2))
    eng.run()
    eng.completed.clear()
    for i, plen in enumerate(PROMPT_LENS):
        prompt = jax.random.randint(jax.random.fold_in(key, i), (plen,),
                                    0, cfg.vocab)
        eng.submit(Request(rid=i, prompt=prompt, max_new=max_new))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    streams = {c.rid: c.tokens for c in done}
    counts = eng.compile_counts()
    n_chunk_shapes = len([b for b in eng.buckets if b <= chunk])
    assert (counts["prefill"] + counts["chunk"] + counts["step"]
            <= len(eng.buckets) + n_chunk_shapes + 1), (
        f"compile bound broken under {placement.describe()}: {counts}")
    return streams, wall, eng, counts


def tp_bench(emit, json_path=None, *, n_slots: int = 4,
             max_len: int = 128, page_size: int = 16, chunk: int = 32,
             max_new: int = 16):
    cfg = REDUCED["deepseek-7b"]()
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    kw = dict(n_slots=n_slots, max_len=max_len, page_size=page_size,
              chunk=chunk, max_new=max_new)

    ref, ref_wall, ref_eng, ref_counts = _drive(
        params, cfg, SingleDevice(), **kw)
    total_new = sum(len(t) for t in ref.values())
    scaling = [{"mesh": "single", "tp": 1, "wall_s": ref_wall,
                "tokens_per_s": total_new / ref_wall}]
    parity = {}
    compiles = {"single": ref_counts}
    for t in MESH_SIZES:
        streams, wall, _, counts = _drive(
            params, cfg, TensorParallel(t), **kw)
        ok = streams == ref
        parity[f"tp{t}"] = bool(ok)
        compiles[f"tp{t}"] = counts
        scaling.append({"mesh": f"model={t}", "tp": t, "wall_s": wall,
                        "tokens_per_s": total_new / wall})
        emit(f"bench.tp.wall.tp{t}", wall * 1e6,
             f"parity={'OK' if ok else 'MISMATCH'} "
             f"{total_new / wall:.1f} tok/s")
        assert ok, (
            f"TP={t} greedy stream diverged from single-device: "
            f"{ {r: (ref[r], streams.get(r)) for r in ref if ref[r] != streams.get(r)} }")

    tp_max = MESH_SIZES[-1]
    traffic = serve_tp_traffic(ref_eng.kv_trace, cfg, n_slots=n_slots,
                               max_len=max_len,
                               page_size=ref_eng.page_size, tp=tp_max,
                               dtype_bytes=4)
    emit("bench.tp.traffic", 0,
         f"per-device {traffic['per_device_bytes']}B vs single "
         f"{traffic['single_bytes']}B (ratio {traffic['ratio']:.2f}x, "
         f"all-reduce {traffic['allreduce_bytes']}B)")
    # acceptance (ISSUE 6): per-device modeled KV+weight bytes drop >= 3x
    # at tp=4, with the all-reduce term included
    assert traffic["ratio"] >= 3.0, (
        f"per-device traffic ratio {traffic['ratio']:.2f} < 3.0 at "
        f"tp={tp_max}")
    assert traffic["allreduce_bytes"] > 0

    result = {"parity": parity, "scaling": scaling, "traffic": traffic,
              "compiles": compiles,
              "config": {"arch": cfg.name, "n_slots": n_slots,
                         "max_len": max_len, "page_size": page_size,
                         "prefill_chunk": chunk,
                         "prompt_lens": PROMPT_LENS,
                         "max_new": max_new, "mesh_sizes": MESH_SIZES,
                         "devices": jax.device_count()}}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main():
    json_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_PR6.json"

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}")

    tp_bench(emit, json_path=json_path)
    print(f"wrote {json_path}")


if __name__ == "__main__":
    main()
