"""Train-step factory: microbatch accumulation, NaN guards, LR schedule,
optional cross-pod int8 gradient compression (shard_map over 'pod').
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ModelConfig
from repro.models import lm
from repro.optim import adamw, compression


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 10_000
    microbatches: int = 1           # gradient accumulation
    compress_pods: bool = False     # int8+EF cross-pod gradient reduce
    remat: bool = True
    skip_nonfinite: bool = True     # fault tolerance: skip bad steps


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    residual: Any                   # EF buffer (empty dict if unused)


def init_state(params, tcfg: TrainConfig) -> TrainState:
    res = (compression.init_residual(params) if tcfg.compress_pods else {})
    return TrainState(params=params, opt=adamw.init(params), residual=res)


def state_logical_specs(param_specs, tcfg: TrainConfig):
    res = param_specs if tcfg.compress_pods else {}
    return TrainState(params=param_specs,
                      opt=adamw.state_specs(param_specs),
                      residual=res)


def fuse_state(state: TrainState, cfg: ModelConfig) -> TrainState:
    """Migrate a seed-layout TrainState (split wq/wk/wv, wg/wi leaves)
    to the fused param layout (DESIGN.md §5), so old training
    checkpoints keep resuming. AdamW moments are per-element, so
    concatenating mu/nu alongside the params is EXACT — the migrated
    state steps bit-identically to the unmigrated one (global-norm
    clipping sums over leaves, invariant under the re-grouping). EF
    residuals (cross-pod compression) mirror the grad tree and fuse the
    same way."""
    from repro.models import lm
    fuse = lambda tree: lm.fuse_params(cfg, tree)   # noqa: E731
    opt = state.opt._replace(mu=fuse(state.opt.mu), nu=fuse(state.opt.nu))
    res = fuse(state.residual) if state.residual else state.residual
    return TrainState(params=fuse(state.params), opt=opt, residual=res)


def _grads_and_metrics(params, batch, cfg, tcfg):
    def loss_fn(p, b):
        return lm.loss_fn(p, b, cfg, remat=tcfg.remat)

    if tcfg.microbatches <= 1:
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    n = tcfg.microbatches
    micro = jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

    def acc_step(carry, mb):
        g_acc, m_acc = carry
        (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(
            lambda a, b: a + b.astype(jnp.float32) / n, g_acc, g)
        m_acc = jax.tree.map(lambda a, b: a + b / n, m_acc, m)
        return (g_acc, m_acc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    m0 = {"loss": 0.0, "aux_loss": 0.0, "ntokens": 0.0, "accuracy": 0.0}
    m0 = jax.tree.map(lambda x: jnp.zeros((), jnp.float32), m0)
    (grads, metrics), _ = jax.lax.scan(acc_step, (g0, m0), micro)
    return grads, metrics


def _apply_update(state: TrainState, grads, metrics, cfg, tcfg):
    lr_scale = adamw.cosine_schedule(
        state.opt.step, warmup=tcfg.warmup_steps, total=tcfg.total_steps)
    new_params, new_opt, gnorm = adamw.apply(
        tcfg.opt, state.opt, state.params, grads, lr_scale)
    metrics = dict(metrics)
    metrics["grad_norm"] = gnorm
    metrics["lr_scale"] = lr_scale
    if tcfg.skip_nonfinite:
        ok = jnp.isfinite(metrics["loss"]) & jnp.isfinite(gnorm)
        new_params = jax.tree.map(
            lambda n, o: jnp.where(ok, n, o), new_params, state.params)
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(ok, n, o), new_opt,
            state.opt._replace(step=state.opt.step + 1))
        metrics["skipped"] = (~ok).astype(jnp.float32)
    return TrainState(params=new_params, opt=new_opt,
                      residual=state.residual), metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None,
                    param_specs=None):
    """Returns train_step(state, batch) -> (new_state, metrics).

    param_specs (logical spec tree): when given, gradients are pinned to
    the parameter sharding right after AD so the cross-device reduction
    lowers to reduce-scatter instead of a full all-reduce.
    """
    from repro.core import compat, partitioning

    if not tcfg.compress_pods:
        def train_step(state: TrainState, batch):
            grads, metrics = _grads_and_metrics(state.params, batch, cfg,
                                                tcfg)
            if param_specs is not None:
                grads = partitioning.constrain_tree(grads, param_specs)
            return _apply_update(state, grads, metrics, cfg, tcfg)
        return train_step

    assert mesh is not None and "pod" in mesh.axis_names

    def train_step(state: TrainState, batch):
        def body(params, residual, batch_local):
            grads, metrics = _grads_and_metrics(params, batch_local, cfg,
                                                tcfg)
            grads, new_res = compression.compressed_pmean_tree(
                grads, residual, "pod")
            metrics = jax.tree.map(
                lambda m: jax.lax.pmean(m, "pod"), metrics)
            return grads, new_res, metrics

        rep = jax.tree.map(lambda _: P(), state.params)
        batch_spec = jax.tree.map(lambda _: P("pod"), batch)
        metric_spec = {k: P() for k in
                       ("loss", "aux_loss", "ntokens", "accuracy")}
        # manual over 'pod' only; data/model stay GSPMD-auto inside
        fn = compat.shard_map(body, mesh=mesh,
                           in_specs=(rep, rep, batch_spec),
                           out_specs=(rep, rep, metric_spec),
                           axis_names=frozenset({"pod"}),
                           check_vma=False)
        grads, new_res, metrics = fn(state.params, state.residual, batch)
        new_state, metrics = _apply_update(
            state._replace(residual=new_res), grads, metrics, cfg, tcfg)
        return new_state, metrics

    return train_step
