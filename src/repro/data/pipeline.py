"""Deterministic, seekable synthetic token pipeline.

Fault-tolerance primitive: every batch is a pure function of
(seed, step, host) via a counter-based hash, so restart-after-preemption
resumes *exactly* at the failed step with no data replay and no state to
checkpoint beyond the integer step. Host-sharding splits the global
batch across data-parallel hosts.

The token stream is a stationary-AR synthetic language (per-sequence
Markov chain over the vocab) rather than iid noise, so cross-entropy has
learnable structure and training-loss curves are meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    order: int = 2          # Markov order of the synthetic language


def _philox(seed: int, step: int, host: int, n: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed,
                               spawn_key=(step, host)))


class SyntheticLM:
    """Counter-based synthetic LM data: batch(step) is pure & seekable."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts
        # fixed random Markov transition structure (shared across hosts)
        rng = np.random.default_rng(cfg.seed)
        k = min(cfg.vocab, 64)
        self._proj = rng.integers(0, cfg.vocab, size=(k,), dtype=np.int64)
        self._mix = rng.integers(1, 2**31 - 1, size=(cfg.order,),
                                 dtype=np.int64)

    def batch(self, step: int) -> dict:
        """-> {'tokens': (B_local, S) int32, 'labels': same, shifted}."""
        cfg = self.cfg
        rng = _philox(cfg.seed, step, cfg.host_id, 0)
        b, s = self.local_batch, cfg.seq_len
        noise = rng.integers(0, cfg.vocab, size=(b, s + 1), dtype=np.int64)
        toks = np.empty((b, s + 1), dtype=np.int64)
        toks[:, :cfg.order] = noise[:, :cfg.order]
        k = len(self._proj)
        for t in range(cfg.order, s + 1):
            h = np.zeros(b, dtype=np.int64)
            for j, m in enumerate(self._mix):
                h = h * 1000003 + toks[:, t - 1 - j] * int(m)
            det = self._proj[np.abs(h) % k]
            use_noise = (noise[:, t] % 5) == 0        # 20% noise
            toks[:, t] = np.where(use_noise, noise[:, t] % cfg.vocab, det)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def iter_from(self, step: int) -> Iterator[dict]:
        while True:
            yield self.batch(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch (overlap host data gen with device step)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        import queue
        import threading
        self._q = queue.Queue(maxsize=depth)
        self._it = it
        self._done = False

        def worker():
            for item in it:
                if self._done:
                    return
                self._q.put(item)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._done = True
