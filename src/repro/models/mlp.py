"""Feed-forward layers (the FC layers that dominate the paper's Fig. 2).

Variants: GELU MLP (2 mats), SwiGLU / GeGLU (3 mats), RWKV channel-mix
(relu^2 + receptance gate). All matmuls go through the row-wise primitive.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import partitioning as part
from repro.core.types import GATED_ACTS as GATED, ModelConfig
from repro.kernels import ops


def init(key, cfg: ModelConfig, stack: Optional[int], dtype,
         d_ff: Optional[int] = None):
    """Gated variants store the gate|up pair PRE-FUSED as one ``wgi``
    (d, 2*d_ff) leaf (DESIGN.md §5) — gate columns first, up columns
    second — so the gated kernel streams both halves straight from the
    stored panel. Non-gated MLPs keep the single ``wi`` leaf."""
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    lead = () if stack is None else (stack,)
    llead = () if stack is None else ("layers",)
    ks = jax.random.split(key, 3)

    def w(k, din, dout):
        return (jax.random.normal(k, lead + (din, dout), jnp.float32)
                / math.sqrt(din)).astype(dtype)

    if cfg.act in GATED:
        params = {"wgi": w(ks[0], d, 2 * f), "wo": w(ks[1], f, d)}
        specs = {"wgi": llead + ("embed", "ffn"),
                 "wo": llead + ("ffn", "embed")}
    else:
        params = {"wi": w(ks[0], d, f), "wo": w(ks[1], f, d)}
        specs = {"wi": llead + ("embed", "ffn"),
                 "wo": llead + ("ffn", "embed")}
    return params, specs


def apply(params, x, *, cfg: ModelConfig, norm=None, residual=None):
    """``norm``/``residual`` select the fused pipeline (DESIGN.md §3):
    the pre-norm runs as the first kernel's prologue, gated variants
    stream the stored wg|wi panel through ONE kernel whose epilogue
    computes ``act(g) * h``, and the residual add rides the output
    projection's epilogue. With both None this is the seed's per-op
    composition (the stored panel sliced back into wg and wi)."""
    act = {"silu": "silu", "geglu": "gelu", "gelu": "gelu",
           "relu": "relu"}[cfg.act]
    if cfg.act in GATED:
        if norm is not None:
            h = ops.gate_up_proj(x, params["wgi"], activation=act,
                                 norm=norm)
        else:
            from repro.core import quant
            wgi = quant.resolve_weight(params["wgi"], x.dtype)
            f = wgi.shape[-1] // 2
            g = ops.matmul(x, wgi[..., :f], activation=act)
            h = ops.matmul(x, wgi[..., f:]) * g
    else:
        h = ops.matmul(x, params["wi"], activation=act, norm=norm)
    if part.tp_axis() is None:
        return ops.matmul(h, params["wo"], residual=residual)
    # TP serving: wo is row-sharded over the hidden dim — psum the
    # partial product over the mesh axis before the residual rides on
    y = part.tp_reduce(ops.matmul(h, params["wo"]))
    return y if residual is None else y + residual


# ---------------------------- RWKV channel-mix -------------------------


def init_cmix(key, cfg: ModelConfig, stack: Optional[int], dtype):
    d, f = cfg.d_model, cfg.d_ff
    lead = () if stack is None else (stack,)
    llead = () if stack is None else ("layers",)
    ks = jax.random.split(key, 4)

    def w(k, din, dout):
        return (jax.random.normal(k, lead + (din, dout), jnp.float32)
                / math.sqrt(din)).astype(dtype)

    params = {"wk": w(ks[0], d, f), "wv": w(ks[1], f, d),
              "wr": w(ks[2], d, d),
              "mu_k": jnp.full(lead + (d,), 0.5, dtype),
              "mu_r": jnp.full(lead + (d,), 0.5, dtype)}
    specs = {"wk": llead + ("embed", "ffn"), "wv": llead + ("ffn", "embed"),
             "wr": llead + ("embed", "embed"),
             "mu_k": llead + (None,), "mu_r": llead + (None,)}
    return params, specs


def apply_cmix(params, x, x_prev):
    """RWKV6 channel-mix. x: (B,S,d); x_prev: token-shifted x."""
    xk = x + (x_prev - x) * params["mu_k"].astype(x.dtype)
    xr = x + (x_prev - x) * params["mu_r"].astype(x.dtype)
    k = ops.matmul(xk, params["wk"], activation="relu2")
    r = jax.nn.sigmoid(ops.matmul(xr, params["wr"]).astype(jnp.float32))
    v = ops.matmul(k, params["wv"])
    return (r * v.astype(jnp.float32)).astype(x.dtype)
