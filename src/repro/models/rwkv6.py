"""RWKV6 "Finch" time-mix — attention-free, data-dependent per-channel decay.

The WKV recurrence has no dot-product-primitive form, so the paper's
row-wise technique applies only to the R/K/V/G/O projections (>=80% of
FLOPs; see DESIGN.md §5). The recurrence itself runs chunkwise:

    y_t = sum_c r_t[c] * (S_{t-1}[c,:] + u[c] k_t[c] v_t)
    S_t[c,:] = w_t[c] * S_{t-1}[c,:] + k_t[c] * v_t
    w_t = exp(-exp(w0 + lora(x_t)))          (data-dependent decay)

Chunked numerics: per-step log decays are clamped to [-CLAMP, -1e-6].
With chunk=16 and CLAMP=3.5 the largest intermediate factor is
exp(16*3.5) ~ 2e24 (fp32-safe) while anything the clamp affects has
decayed below fp32 epsilon — semantically lossless.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig
from repro.kernels import ops

CHUNK = 16
CLAMP = 3.5
# Backward recomputes intra-chunk tensors from the chunk-boundary WKV
# states instead of materializing every chunk's rd/kd/A products (the
# scan-AD default stacks them: ~6 GB f32 per layer at 4k tokens).
# See EXPERIMENTS.md §Perf (rwkv6 train_4k iteration 1).
BOUNDARY_RECOMPUTE = True


class RWKVState(NamedTuple):
    x_prev_t: jnp.ndarray   # (B, d) last input of time-mix
    x_prev_c: jnp.ndarray   # (B, d) last input of channel-mix
    wkv: jnp.ndarray        # (B, H, hd, hd) recurrence state


def init(key, cfg: ModelConfig, stack: Optional[int], dtype):
    r = cfg.rwkv
    d = cfg.d_model
    h = d // r.head_dim
    lead = () if stack is None else (stack,)
    llead = () if stack is None else ("layers",)
    ks = jax.random.split(key, 8)

    def w(k, din, dout, scale=1.0):
        return (jax.random.normal(k, lead + (din, dout), jnp.float32)
                * scale / math.sqrt(din)).astype(dtype)

    params = {
        "wr": w(ks[0], d, d), "wk": w(ks[1], d, d), "wv": w(ks[2], d, d),
        "wg": w(ks[3], d, d), "wo": w(ks[4], d, d),
        "w0": jnp.full(lead + (d,), -2.0, jnp.float32),
        "w_lora_a": w(ks[5], d, r.decay_lora, 0.1),
        "w_lora_b": (jnp.zeros(lead + (r.decay_lora, d), jnp.float32)
                     ).astype(dtype),
        "u": (jax.random.normal(ks[6], lead + (h, r.head_dim), jnp.float32)
              * 0.1).astype(jnp.float32),
        "mu": (0.5 * jnp.ones(lead + (5, d), jnp.float32)).astype(dtype),
        "ln_g": jnp.ones(lead + (d,), dtype),
        "ln_b": jnp.zeros(lead + (d,), dtype),
    }
    specs = {
        "wr": llead + ("embed", "qkv"), "wk": llead + ("embed", "qkv"),
        "wv": llead + ("embed", "qkv"), "wg": llead + ("embed", "qkv"),
        "wo": llead + ("qkv", "embed"),
        "w0": llead + (None,), "w_lora_a": llead + ("embed", None),
        "w_lora_b": llead + (None, "embed"), "u": llead + (None, None),
        "mu": llead + (None, None), "ln_g": llead + (None,),
        "ln_b": llead + (None,),
    }
    return params, specs


def wkv_chunked(r, k, v, lw, u, *, chunk: int = CHUNK, s0=None):
    """Chunked WKV6. r,k,v: (B,S,H,P); lw: (B,S,H,P) log decay (<0);
    u: (H,P). Returns (y (B,S,H,P), final state (B,H,P,P))."""
    b, sl, h, p = r.shape
    chunk = min(chunk, sl)
    pad = (-sl) % chunk
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        lw = jnp.pad(lw, z)  # pad with 0 log-decay; ok, tokens unused
    nc = (sl + pad) // chunk

    def resh(x):
        return x.reshape(b, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, lwc = resh(r), resh(k), resh(v), resh(lw)
    if s0 is None:
        s0 = jnp.zeros((b, h, p, p), jnp.float32)

    idx = jnp.arange(chunk)
    strict = idx[:, None] > idx[None, :]          # j < i

    def step(S, inp):
        rk, kk, vk, lwk = inp                     # (B,L,H,P)
        cs = jnp.cumsum(lwk, axis=1)              # inclusive
        cs_prev = cs - lwk                        # exclusive: sum_{t<i}
        # intra: A[i,j] = sum_c r_i[c] k_j[c] exp(cs_prev_i - cs_j), j<i
        rd = rk * jnp.exp(cs_prev)                # (B,L,H,P)
        kd = kk * jnp.exp(-cs)
        A = jnp.einsum("bihp,bjhp->bhij", rd, kd)
        A = jnp.where(strict[None, None], A, 0.0)
        # diagonal bonus term: (r_i . u k_i)
        diag = jnp.einsum("bihp,hp,bihp->bih", rk, u, kk)
        y = (jnp.einsum("bhij,bjhp->bihp", A, vk)
             + diag[..., None] * vk)
        # inter: y_i += sum_c r_i[c] exp(cs_prev_i[c]) S[c,:]
        y = y + jnp.einsum("bihp,bhpq->bihq", rd, S)
        # state: S' = diag(exp(cs_L)) S + sum_j exp(cs_L - cs_j) k_j v_j
        tail = jnp.exp(cs[:, -1:] - cs)           # (B,L,H,P)
        S_new = (jnp.exp(cs[:, -1])[..., None] * S
                 + jnp.einsum("bjhp,bjhq->bhpq", tail * kk, vk))
        return S_new, y

    if BOUNDARY_RECOMPUTE:
        step = jax.checkpoint(step, prevent_cse=False)
    S_fin, ys = jax.lax.scan(step, s0, (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, p)
    return y[:, :sl], S_fin


def wkv_ref(r, k, v, lw, u, s0=None):
    """Naive per-step oracle."""
    b, sl, h, p = r.shape
    S = jnp.zeros((b, h, p, p), jnp.float32) if s0 is None else s0

    def step(S, inp):
        rt, kt, vt, lwt = inp                     # (B,H,P)
        kv = jnp.einsum("bhp,bhq->bhpq", kt, vt)
        y = jnp.einsum("bhp,bhpq->bhq", rt, S + u[..., None] * kv)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, y

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, lw))
    S, ys = jax.lax.scan(step, S, xs)
    return ys.transpose(1, 0, 2, 3), S


def _token_shift(x, x_prev_last):
    """x_{t-1} stream: shift right; position 0 uses carried state."""
    return jnp.concatenate([x_prev_last[:, None], x[:, :-1]], axis=1)


def apply(params, x, *, cfg: ModelConfig, state: Optional[dict] = None):
    """Time-mix forward. x: (B,S,d); state: {'x_prev_t': (B,d),
    'wkv': (B,H,P,P)} or None. Returns (out, (new_x_prev, new_wkv))."""
    rr = cfg.rwkv
    b, sl, d = x.shape
    h, p = d // rr.head_dim, rr.head_dim
    x_last = (state["x_prev_t"] if state is not None
              else jnp.zeros_like(x[:, 0]))
    xp = _token_shift(x, x_last)
    mu = params["mu"].astype(x.dtype)             # (5, d)
    xr = x + (xp - x) * mu[0]
    xk = x + (xp - x) * mu[1]
    xv = x + (xp - x) * mu[2]
    xg = x + (xp - x) * mu[3]
    xw = x + (xp - x) * mu[4]
    r = ops.matmul(xr, params["wr"]).reshape(b, sl, h, p).astype(jnp.float32)
    k = ops.matmul(xk, params["wk"]).reshape(b, sl, h, p).astype(jnp.float32)
    v = ops.matmul(xv, params["wv"]).reshape(b, sl, h, p).astype(jnp.float32)
    g = ops.matmul(xg, params["wg"])
    # data-dependent decay (the Finch contribution)
    lora = jnp.tanh(ops.matmul(xw, params["w_lora_a"],
                               out_dtype=jnp.float32))
    wlog = params["w0"] + ops.matmul(
        lora.astype(x.dtype), params["w_lora_b"], out_dtype=jnp.float32)
    lw = -jnp.exp(wlog).reshape(b, sl, h, p)
    lw = jnp.clip(lw, -CLAMP, -1e-6)
    s0 = state["wkv"] if state is not None else None
    y, s_fin = ops.wkv(r, k, v, lw, params["u"], s0=s0)
    y = y.reshape(b, sl, d).astype(x.dtype)
    y = ops.layernorm(y, params["ln_g"], params["ln_b"], kind="layer")
    y = (y.astype(jnp.float32)
         * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    out = ops.matmul(y, params["wo"])
    return out, (x[:, -1], s_fin)
