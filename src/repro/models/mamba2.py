"""Mamba2 (SSD) mixer — zamba2's backbone.

The selective-scan recurrence has no dot-product-primitive form (noted
in DESIGN.md §5): the paper's row-wise technique applies to the in/out
projections only. The scan itself uses the SSD *chunked* formulation —
intra-chunk attention-like term + inter-chunk state passing — which maps
onto TPU as dense (L x L)-per-head matmuls, scanned over chunks.

Recurrence (per head h, head dim P, state dim N, scalar decay):
    S_t = exp(dt_t * a_h) * S_{t-1} + dt_t * x_t (outer) B_t
    y_t = S_t C_t + D_h x_t
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig
from repro.kernels import ops


class MambaState(NamedTuple):
    conv: jnp.ndarray   # (B, d_conv-1, conv_dim) rolling conv inputs
    ssm: jnp.ndarray    # (B, H, P, N) state


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return s, d_in, n_heads, conv_dim


def init(key, cfg: ModelConfig, stack: Optional[int], dtype):
    s, d_in, n_heads, conv_dim = _dims(cfg)
    d = cfg.d_model
    lead = () if stack is None else (stack,)
    llead = () if stack is None else ("layers",)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * s.d_state + n_heads

    def w(k, din, dout):
        return (jax.random.normal(k, lead + (din, dout), jnp.float32)
                / math.sqrt(din)).astype(dtype)

    params = {
        "in_proj": w(ks[0], d, proj_out),
        "out_proj": w(ks[1], d_in, d),
        "conv_w": (jax.random.normal(ks[2], lead + (s.d_conv, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros(lead + (conv_dim,), dtype),
        "A_log": jnp.zeros(lead + (n_heads,), jnp.float32),
        "dt_bias": jnp.zeros(lead + (n_heads,), jnp.float32),
        "D": jnp.ones(lead + (n_heads,), jnp.float32),
        "norm_g": jnp.ones(lead + (d_in,), dtype),
    }
    specs = {
        "in_proj": llead + ("embed", "ffn"),
        "out_proj": llead + ("ffn", "embed"),
        "conv_w": llead + (None, "ffn"), "conv_b": llead + ("ffn",),
        "A_log": llead + (None,), "dt_bias": llead + (None,),
        "D": llead + (None,), "norm_g": llead + ("ffn",),
    }
    return params, specs


def _split(cfg, zxbcdt):
    s, d_in, n_heads, _ = _dims(cfg)
    z, x, bc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + 2 * s.d_state], axis=-1)
    return z, x, bc, dt


def _conv(x, w, b, state=None):
    """Causal depthwise conv. x: (B,S,C); w: (K,C). state: (B,K-1,C)."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return out + b, new_state


def ssd_chunked(xh, dt, a, B, C, *, chunk: int = 128, s0=None):
    """Chunked SSD scan.

    xh: (Bb, S, H, P); dt: (Bb, S, H); a: (H,) negative;
    B, C: (Bb, S, N). Returns (y, final_state (Bb,H,P,N)).
    """
    bb, sl, h, p = xh.shape
    n = B.shape[-1]
    chunk = min(chunk, sl)
    pad = (-sl) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = (sl + pad) // chunk
    xc = xh.reshape(bb, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(bb, nc, chunk, h).transpose(1, 0, 2, 3)
    Bc = B.reshape(bb, nc, chunk, n).transpose(1, 0, 2, 3)
    Cc = C.reshape(bb, nc, chunk, n).transpose(1, 0, 2, 3)

    if s0 is None:
        s0 = jnp.zeros((bb, h, p, n), jnp.float32)

    def step(S, inp):
        xk, dk, Bk, Ck = inp                      # (Bb,L,H,P),(Bb,L,H),...
        lam = dk * a                              # (Bb,L,H) log decays <=0
        cs = jnp.cumsum(lam, axis=1)              # inclusive cumsum
        # intra-chunk: M[b,h,i,j] = exp(cs_i - cs_j) dt_j (C_i . B_j), j<=i
        logd = cs[:, :, None, :] - cs[:, None, :, :]      # (Bb,i,j,H)
        mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
        logd = jnp.where(mask[None, :, :, None], logd, -jnp.inf)
        cb = jnp.einsum("bin,bjn->bij", Ck, Bk)           # (Bb,i,j)
        M = jnp.exp(logd) * cb[..., None] * dk[:, None, :, :]
        y = jnp.einsum("bijh,bjhp->bihp", M, xk)
        # inter-chunk: y_i += exp(cs_i) * C_i . S^T
        y = y + jnp.exp(cs)[..., None] * jnp.einsum(
            "bhpn,bin->bihp", S, Ck)
        # state update: S' = exp(cs_L) S + sum_j exp(cs_L - cs_j) dt_j x_j B_j
        tail = jnp.exp(cs[:, -1:, :] - cs)                # (Bb,L,H)
        S_new = (jnp.exp(cs[:, -1])[:, :, None, None] * S
                 + jnp.einsum("bjh,bjhp,bjn->bhpn", tail * dk, xk, Bk))
        return S_new, y

    # backward recomputes intra-chunk tensors from boundary states (the
    # scan-AD default stacks every chunk's decay/score products in HBM)
    step = jax.checkpoint(step, prevent_cse=False)
    S_fin, ys = jax.lax.scan(step, s0, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bb, nc * chunk, h, p)
    return y[:, :sl], S_fin


def ssd_ref(xh, dt, a, B, C, s0=None):
    """Naive per-step scan oracle."""
    bb, sl, h, p = xh.shape
    n = B.shape[-1]
    S = jnp.zeros((bb, h, p, n), jnp.float32) if s0 is None else s0

    def step(S, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * a)                  # (Bb,H)
        S = (S * decay[:, :, None, None]
             + jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, Bt))
        y = jnp.einsum("bhpn,bn->bhp", S, Ct)
        return S, y

    xs = (xh.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          B.transpose(1, 0, 2), C.transpose(1, 0, 2))
    S, ys = jax.lax.scan(step, S, xs)
    return ys.transpose(1, 0, 2, 3), S


def apply(params, x, *, cfg: ModelConfig, state: Optional[MambaState] = None,
          chunk: Optional[int] = None):
    """Full-sequence forward. x: (B,S,d). Returns (out, final_state)."""
    s, d_in, n_heads, conv_dim = _dims(cfg)
    bsz, sl, _ = x.shape
    zxbcdt = ops.matmul(x, params["in_proj"])
    z, xi, bc, dt = _split(cfg, zxbcdt)
    conv_in = jnp.concatenate([xi, bc], axis=-1)
    conv_state = state.conv if state is not None else None
    conv_out, new_conv = _conv(conv_in, params["conv_w"].astype(jnp.float32),
                               params["conv_b"].astype(jnp.float32),
                               conv_state)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32))
    xi = conv_out[..., :d_in]
    B = conv_out[..., d_in:d_in + s.d_state]
    C = conv_out[..., d_in + s.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])                 # (B,S,H)
    a = -jnp.exp(params["A_log"])                             # (H,)
    xh = xi.reshape(bsz, sl, n_heads, s.head_dim)
    y, s_fin = ssd_chunked(xh, dt, a, B, C, chunk=chunk or s.chunk,
                           s0=state.ssm if state is not None else None)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(bsz, sl, d_in)
    # gated RMSNorm then out-projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = ops.layernorm(y.astype(x.dtype), params["norm_g"], kind="rms")
    out = ops.matmul(y, params["out_proj"])
    new_state = MambaState(conv=new_conv.astype(x.dtype), ssm=s_fin)
    return out, new_state


def init_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    s, d_in, n_heads, conv_dim = _dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32))


def state_specs():
    return MambaState(conv=("batch", None, "ffn"),
                      ssm=("batch", None, None, None))
