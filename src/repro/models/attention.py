"""Attention layer: GQA/MQA, RoPE/M-RoPE, sliding windows, KV caches.

Three execution paths, all funneling the projections through the
row-wise matmul primitive (the paper's unification):

  * ``dense``   — materialized scores; small sequences / smoke tests.
  * ``chunked`` — jnp online-softmax scan over KV blocks; sub-quadratic
                  memory; what the dry-run lowers (flash-equivalent HLO).
  * ``pallas``/``interpret`` — the row-wise flash kernel.

Decode uses a flash-decode formulation (chunked over the cache with a
running log-sum-exp), optionally sequence-sharded over the model axis
via shard_map with a psum LSE combine (see serve/).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import runtime
from repro.core import compat
from repro.core import partitioning as part
from repro.core.partitioning import logical_constraint
from repro.core.types import ModelConfig
from repro.kernels import ops
from repro.models import rope as rope_lib

DENSE_MAX_SEQ = 2048      # above this, 'ref' impl switches to chunked


def proj_splits(cfg: ModelConfig):
    """(q, k, v) output widths inside the fused ``wqkv`` panel."""
    qo = cfg.n_heads * cfg.head_dim
    kvo = cfg.n_kv_heads * cfg.head_dim
    return (qo, kvo, kvo)


def init(key, cfg: ModelConfig, stack: Optional[int], dtype,
         cross: bool = False):
    """Returns (params, logical_specs). stack=None => unstacked (shared).

    Projection weights are stored PRE-FUSED (DESIGN.md §5): self
    attention keeps one ``wqkv`` (d, (Hq + 2*Hkv) * hd) leaf — q, k and
    v column panels concatenated at init time, so the serving hot path
    never pays a per-call weight concatenate. Cross attention (whisper)
    projects q from the decoder stream but k/v from the encoder output,
    so it keeps ``wq`` separate and fuses the encoder-side pair into
    one ``wkv`` (d, 2*Hkv*hd) leaf. ``lm.unfuse_params`` recovers the
    seed's split layout (checkpoint migration).
    """
    d, hd = cfg.d_model, cfg.head_dim
    qo, kvo = cfg.n_heads * hd, cfg.n_kv_heads * hd
    lead = () if stack is None else (stack,)
    llead = () if stack is None else ("layers",)
    ks = jax.random.split(key, 4)

    def w(k, din, dout, scale=1.0):
        std = scale / math.sqrt(din)
        return (jax.random.normal(k, lead + (din, dout), jnp.float32)
                * std).astype(dtype)

    if cross:
        params = {"wq": w(ks[0], d, qo), "wkv": w(ks[1], d, 2 * kvo),
                  "wo": w(ks[3], qo, d)}
        specs = {"wq": llead + ("embed", "qkv"),
                 "wkv": llead + ("embed", "qkv"),
                 "wo": llead + ("qkv", "embed")}
    else:
        params = {"wqkv": w(ks[0], d, qo + 2 * kvo), "wo": w(ks[3], qo, d)}
        specs = {"wqkv": llead + ("embed", "qkv"),
                 "wo": llead + ("qkv", "embed")}
    return params, specs


def _out_proj(out, wo, residual):
    """Output projection, TP-aware (serve/placement.py). Under a
    tensor-parallel shard context ``wo`` is row-sharded (each shard
    holds the head group it attended), so the matmul yields a K-partial
    sum that must psum over the TP axis BEFORE the residual rides on —
    a residual folded into the kernel epilogue would be summed once per
    shard. Outside TP this is exactly the fused epilogue path."""
    if part.tp_axis() is None:
        return ops.matmul(out, wo, residual=residual)
    y = part.tp_reduce(ops.matmul(out, wo))
    return y if residual is None else y + residual


class KVCache(NamedTuple):
    """Per-layer KV cache. k/v: (B, S_alloc, Hkv, hd).

    For sliding-window layers S_alloc == window and writes wrap around
    (ring buffer); ``length`` tracking lives with the serving state.
    """
    k: jnp.ndarray
    v: jnp.ndarray


def init_cache(cfg: ModelConfig, batch: int, alloc_len: int, dtype,
               window: int = 0):
    s = min(alloc_len, window) if window else alloc_len
    shape = (batch, s, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def cache_specs(window: int = 0):
    names = ("batch", "kv_seq", "kv_heads", None)
    return KVCache(k=names, v=names)


class PagedKVCache(NamedTuple):
    """Per-layer paged KV pool. k/v: (n_pages + n_slots, page_size,
    Hkv, hd).

    Physical pages are shared by every slot in the serving batch; the
    logical order of a slot's tokens lives in the engine's block table
    ((B, max_pages) int32: logical page ``l`` of row ``b`` is physical
    page ``table[b, l]``). The last ``n_slots`` physical pages are
    per-slot scratch pages — idle and mid-prefill slots' tables point
    at their own row so lockstep writes from those slots never touch
    live storage (and never serialize on one shared page).
    Sliding-window layers reuse the first ``window // page_size`` table
    entries as a ring of pages.
    """
    k: jnp.ndarray
    v: jnp.ndarray


def _apply_rope(q, k, cfg: ModelConfig, positions):
    if cfg.rope == "none":
        return q, k
    if cfg.rope == "mrope":
        if positions.ndim == 2:            # text-only: (B,S) -> (3,B,S)
            positions = rope_lib.text_positions3(positions)
        q = rope_lib.apply_mrope(q, positions, cfg.rope_theta)
        k = rope_lib.apply_mrope(k, positions, cfg.rope_theta)
    else:
        q = rope_lib.apply_rope(q, positions, cfg.rope_theta)
        k = rope_lib.apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _chunk_mask(base, chunk, q_pos, limit, causal, window):
    """(B,1,1,Sq,chunk) validity mask for one KV chunk."""
    k_pos = base + jnp.arange(chunk)                           # (chunk,)
    mask = (k_pos[None, :] < limit[:, None])[:, None, None, None, :]
    if causal:
        mask = jnp.logical_and(mask,
                               (k_pos[None, :] <= q_pos)[None, None, None])
    if window > 0:
        mask = jnp.logical_and(
            mask, (k_pos[None, :] > q_pos - window)[None, None, None])
    return mask


def _online_update(carry, qg, kb, vb, mask, scale):
    """One online-softmax accumulation step over a KV chunk — the shared
    row-wise LSE math of the dense-chunk and page-gather paths.

    carry: (m, l, acc) running max / denominator / output accumulator;
    qg: (B,Hkv,g,Sq,hd); kb/vb: (B,Hkv,chunk,hd); mask broadcastable to
    the (B,Hkv,g,Sq,chunk) score shape. q/k stay in model dtype; the
    MXU accumulates in f32 (no materialized f32 operand copies).
    """
    m, l, acc = carry
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kb,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    m_new = jnp.maximum(m, jnp.max(s, -1))
    p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + jnp.sum(p, -1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
        preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def _chunked_fwd(q, k, v, limit, *, causal, window, q_offset, chunk):
    """Returns (out (B,Hq,Sq,hd), lse (B,Hkv,g,Sq) fp32)."""
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = (skv + pad) // chunk
    kc = k.reshape(b, hkv, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    qg = q.reshape(b, hkv, g, sq, hd)
    scale = hd ** -0.5
    q_pos = q_offset + jnp.arange(sq)[:, None]                 # (Sq,1)

    def step(carry, inp):
        # NB: the chunk base position rides in the carry (not the xs) so
        # XLA cannot hoist/stack the position masks for every chunk — the
        # hoisted form materializes a full Sq x Skv mask in HBM.
        m, l, acc, base = carry
        kb, vb = inp
        mask = _chunk_mask(base, chunk, q_pos, limit, causal, window)
        m_new, l_new, acc_new = _online_update((m, l, acc), qg, kb, vb,
                                               mask, scale)
        return (m_new, l_new, acc_new, base + chunk), None

    m0 = jnp.full((b, hkv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(
        step, (m0, l0, a0, jnp.zeros((), jnp.int32)), (kc, vc))
    l = jnp.maximum(l, 1e-30)
    out = acc / l[..., None]
    lse = m + jnp.log(l)
    return out.reshape(b, hq, sq, hd).astype(q.dtype), lse


def _paged_fwd(q, k_pool, v_pool, pages, limit, *, chunk, q_offset=None,
               window: int = 0):
    """Online-softmax over a paged KV pool — the same row-wise LSE math
    as :func:`_chunked_fwd`, but each scan chunk *gathers* its KV rows
    from the pool through the block table instead of slicing a dense
    per-slot cache, so only a slot's live pages ever stream.

    q: (B,Hq,Sq,hd); k_pool/v_pool: (n_pages, page_size, Hkv, hd);
    pages: (B, n_logical_pages) int32 block table; limit: (B,) valid
    token counts (logical positions >= limit are masked out).

    ``q_offset`` ((B,) int32) turns the single-position decode gather
    into a multi-query *prefix* gather for chunked prefill: query row i
    sits at absolute position ``q_offset + i`` and attends causally
    (vacuous while every cached key is below ``limit <= q_offset``, but
    kept explicit so the mask is correct for any limit). ``window``
    marks the table as a sliding-window *ring* of ``window / page_size``
    pages: ring slot r holds the newest written position ≡ r (mod
    window) strictly below ``limit``, and each query additionally masks
    keys at or below ``q_pos - window``. The decode path (q_offset=None,
    window=0) is bit-identical to before.
    Returns (out (B,Hq,Sq,hd), lse (B,Hkv,g,Sq) fp32).
    """
    b, hq, sq, hd = q.shape
    _, ps, hkv, _ = k_pool.shape
    g = hq // hkv
    n_log = pages.shape[1]
    ppc = max(1, min(n_log, chunk // ps))      # pages gathered per chunk
    pad = (-n_log) % ppc
    if pad:
        # padding repeats the table's last entry; fully masked below
        pages = jnp.pad(pages, ((0, 0), (0, pad)), mode="edge")
    nc = (n_log + pad) // ppc
    pid_chunks = pages.reshape(b, nc, ppc).transpose(1, 0, 2)  # (nc,B,ppc)
    bases = jnp.arange(nc) * (ppc * ps)
    qg = q.reshape(b, hkv, g, sq, hd)
    scale = hd ** -0.5

    def step(carry, inp):
        pid, base = inp                                        # (B,ppc)
        kb = jnp.take(k_pool, pid, axis=0)   # (B, ppc, ps, Hkv, hd)
        vb = jnp.take(v_pool, pid, axis=0)
        kb = kb.reshape(b, ppc * ps, hkv, hd).transpose(0, 2, 1, 3)
        vb = vb.reshape(b, ppc * ps, hkv, hd).transpose(0, 2, 1, 3)
        r = base + jnp.arange(ppc * ps)      # logical slot index
        if window:
            # ring: recover the absolute position each slot holds (the
            # newest p ≡ r (mod window) below limit); unwritten slots
            # (limit < window) resolve negative and mask out, padded
            # table slots (r >= window) are never ring storage
            k_pos = (r[None, :] + ((limit[:, None] - 1 - r[None, :])
                                   // window) * window)        # (B, K)
            valid = ((r[None, :] < window) & (k_pos >= 0)
                     & (k_pos < limit[:, None]))
        else:
            k_pos = jnp.broadcast_to(r[None, :], (b, r.shape[0]))
            valid = k_pos < limit[:, None]
        if q_offset is None:
            mask = valid[:, None, None, None, :]
        else:
            q_pos = q_offset[:, None] + jnp.arange(sq)[None]   # (B, Sq)
            qm = k_pos[:, None, :] <= q_pos[..., None]         # causal
            if window:
                qm &= k_pos[:, None, :] > (q_pos[..., None] - window)
            mask = (valid[:, None, :] & qm)[:, None, None, :, :]
        return _online_update(carry, qg, kb, vb, mask, scale), None

    m0 = jnp.full((b, hkv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (pid_chunks, bases))
    l = jnp.maximum(l, 1e-30)
    out = acc / l[..., None]
    return out.reshape(b, hq, sq, hd).astype(q.dtype), m + jnp.log(l)


def _flash_bwd(res, dout, *, causal, window, q_offset, chunk):
    """Flash-attention backward: recompute p per chunk from saved lse —
    no stacked score saves (the scan-AD default materializes every
    chunk's probabilities for the backward; this is the row-wise
    kernel's recompute-from-stats strategy in jnp)."""
    q, k, v, limit, out, lse = res
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = (skv + pad) // chunk
    kc = k.reshape(b, hkv, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    qg = q.reshape(b, hkv, g, sq, hd)
    do = dout.reshape(b, hkv, g, sq, hd)
    og = out.reshape(b, hkv, g, sq, hd)
    scale = hd ** -0.5
    q_pos = q_offset + jnp.arange(sq)[:, None]
    d_term = jnp.einsum("bhgqd,bhgqd->bhgq", do, og,
                        preferred_element_type=jnp.float32)

    def step(carry, inp):
        dq_acc, base = carry
        kb, vb = inp
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        mask = _chunk_mask(base, chunk, q_pos, limit, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse[..., None]), 0.0)
        pb = p.astype(vb.dtype)
        dv = jnp.einsum("bhgqk,bhgqd->bhkd", pb, do,
                        preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", do, vb,
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - d_term[..., None]) * scale)
        dsb = ds.astype(kb.dtype)
        dq_acc = dq_acc + jnp.einsum("bhgqk,bhkd->bhgqd", dsb, kb,
                                     preferred_element_type=jnp.float32)
        dk = jnp.einsum("bhgqk,bhgqd->bhkd", dsb, qg,
                        preferred_element_type=jnp.float32)
        return (dq_acc, base + chunk), (dk, dv)

    dq0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    (dq, _), (dks, dvs) = jax.lax.scan(
        step, (dq0, jnp.zeros((), jnp.int32)), (kc, vc))
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nc * chunk, hd)
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nc * chunk, hd)
    dq = dq.reshape(b, hq, sq, hd)
    return (dq.astype(q.dtype), dk[:, :, :skv].astype(k.dtype),
            dv[:, :, :skv].astype(v.dtype), None)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _chunked_attention_diff(q, k, v, limit, causal, window, q_offset,
                            chunk):
    out, _ = _chunked_fwd(q, k, v, limit, causal=causal, window=window,
                          q_offset=q_offset, chunk=chunk)
    return out


def _cad_fwd(q, k, v, limit, causal, window, q_offset, chunk):
    out, lse = _chunked_fwd(q, k, v, limit, causal=causal, window=window,
                            q_offset=q_offset, chunk=chunk)
    return out, (q, k, v, limit, out, lse)


def _cad_bwd(causal, window, q_offset, chunk, res, dout):
    return _flash_bwd(res, dout, causal=causal, window=window,
                      q_offset=q_offset, chunk=chunk)


_chunked_attention_diff.defvjp(_cad_fwd, _cad_bwd)


def chunked_attention(q, k, v, *, causal=True, window: int = 0,
                      q_offset=0, kv_len=None, chunk: int = 1024,
                      pages=None):
    """Online-softmax scan over KV chunks. q: (B,Hq,Sq,hd); k/v GQA.

    q_offset may be a traced scalar (decode). kv_len masks padded cache.
    The train path (static offset, no kv_len) uses the flash custom-VJP.

    pages: optional (B, n_logical_pages) int32 block table. When given,
    k/v are page *pools* (n_pages, page_size, Hkv, hd) and every chunk
    gathers its KV rows through the table (paged decode; causality and
    windowing are expressed through kv_len by the caller).
    """
    b = q.shape[0]
    if pages is not None:
        limit = jnp.broadcast_to(jnp.asarray(kv_len), (b,))
        with jax.named_scope("rowwise_paged_attn"):
            out, _ = _paged_fwd(q, k, v, pages, limit, chunk=chunk)
        return out
    skv = k.shape[2]
    limit = skv if kv_len is None else kv_len
    limit = jnp.broadcast_to(jnp.asarray(limit), (b,))
    with jax.named_scope("rowwise_attn"):
        if isinstance(q_offset, int) and kv_len is None:
            return _chunked_attention_diff(q, k, v, limit, causal, window,
                                           q_offset, chunk)
        out, _ = _chunked_fwd(q, k, v, limit, causal=causal, window=window,
                              q_offset=q_offset, chunk=chunk)
        return out


def _sdpa(q, k, v, *, causal, window, q_offset=0, kv_len=None):
    """Impl dispatch for the core attention op."""
    impl = runtime.resolve_impl()
    static_off = isinstance(q_offset, int)
    if impl == "ref":
        if (q.shape[2] <= DENSE_MAX_SEQ and k.shape[2] <= DENSE_MAX_SEQ
                and static_off and kv_len is None):
            return ops.attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, impl="ref")
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, kv_len=kv_len)
    if not static_off or kv_len is not None:
        # kernel path currently takes static offsets; decode goes chunked
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, kv_len=kv_len)
    return ops.attention(q, k, v, causal=causal, window=window,
                         q_offset=q_offset, impl=impl)


def apply(params, x, *, cfg: ModelConfig, positions, window: int = 0,
          causal: bool = True, kv: Optional[tuple] = None,
          norm: Optional[ops.NormSpec] = None, residual=None):
    """Full-sequence forward (train / prefill).

    kv: optional (enc_out, enc_out) override for cross-attention — k
    and v must project from the SAME encoder stream (fused wkv panel).
    norm: fused-pipeline mode — x arrives *un-normalized* and the
    pre-norm runs as the qkv kernel's prologue over the stored wq|wk|wv
    panel (one activation fetch for all projections, no per-call
    weight concat). residual: folded into the output projection's
    epilogue.
    Returns (out, (k_heads, v_heads)) — the heads are cached by prefill.
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if kv is None:
        q, k, v = _project_qkv(params, x, cfg, norm)
        q = q.reshape(b, s, hq, hd)
        k = k.reshape(b, s, hkv, hd)
        v = v.reshape(b, s, hkv, hd)
        q, k = _apply_rope(q, k, cfg, positions)
    else:
        xk, xv = kv
        assert xk is xv, (
            "cross-attention projects k AND v from one encoder stream "
            "through the fused wkv panel; distinct k/v sources are not "
            "supported")
        sk = xk.shape[1]
        kvo = hkv * hd
        q = ops.matmul(x, params["wq"], norm=norm).reshape(b, s, hq, hd)
        if runtime.pipeline_fusion():
            k, v = ops.qkv_proj(xk, params["wkv"], (kvo, kvo))
        else:
            # seed per-op baseline: the stored panel sliced back into
            # the two projection launches (as _project_qkv does)
            from repro.core import quant
            wkv = quant.resolve_weight(params["wkv"], xk.dtype)
            k = ops.matmul(xk, wkv[..., :kvo])
            v = ops.matmul(xk, wkv[..., kvo:])
        k = k.reshape(b, sk, hkv, hd)
        v = v.reshape(b, sk, hkv, hd)
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    qh = logical_constraint(qh, "batch", "heads", "seq", None)
    out = _sdpa(qh, kh, vh, causal=causal, window=window)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return _out_proj(out, params["wo"], residual), (k, v)


def write_cache(cache: KVCache, k_new, v_new, pos, window: int = 0):
    """Insert (B, S_new, Hkv, hd) states at position ``pos`` (scalar or
    per-batch (B,) ), ring-buffered when the layer is windowed."""
    alloc = cache.k.shape[1]
    s_new = k_new.shape[1]
    if isinstance(pos, int) or pos.ndim == 0:
        pos = jnp.broadcast_to(jnp.asarray(pos), (cache.k.shape[0],))
    idx = (pos[:, None] + jnp.arange(s_new)[None]) % alloc     # (B,S_new)

    def upd(buf, new):
        bidx = jnp.arange(buf.shape[0])[:, None]
        return buf.at[bidx, idx].set(new.astype(buf.dtype))

    return KVCache(k=upd(cache.k, k_new), v=upd(cache.v, v_new))


def _project_qkv(params, x, cfg: ModelConfig, norm):
    """q/k/v projections from the stored fused ``wqkv`` panel.

    Fused mode (a norm spec rides along): one wide-N kernel launch over
    the pre-concatenated leaf, outputs sliced per projection — no
    per-call weight concatenate anywhere (DESIGN.md §5). Per-op mode
    (norm is None — the seed baseline kept for before/after benches):
    the stored panel is sliced back into the three projection weights
    and each runs as its own launch.
    """
    splits = proj_splits(cfg)
    if norm is not None:
        return ops.qkv_proj(x, params["wqkv"], splits, norm=norm)
    from repro.core import quant
    w = quant.resolve_weight(params["wqkv"], x.dtype)
    qo, kvo, _ = splits
    return (ops.matmul(x, w[..., :qo]),
            ops.matmul(x, w[..., qo:qo + kvo]),
            ops.matmul(x, w[..., qo + kvo:]))


def _decode_qkv(params, x, cfg: ModelConfig, lengths, norm):
    """Shared decode-step projections: q/k/v heads for the new token,
    RoPE'd at the token's position. x: (B, 1, d)."""
    b = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _project_qkv(params, x, cfg, norm)
    q = q.reshape(b, 1, hq, hd)
    k = k.reshape(b, 1, hkv, hd)
    v = v.reshape(b, 1, hkv, hd)
    return _apply_rope(q, k, cfg, lengths[:, None]) + (v,)


def write_pages(pool: PagedKVCache, k_new, v_new, pos, pages,
                window: int = 0):
    """Append the decode token's K/V (B,1,Hkv,hd) at logical position
    ``pos`` (B,) through the block table ``pages`` (B, n_logical).
    Windowed layers treat the first ``window // page_size`` table
    entries as a ring of pages (the paged analog of the dense ring
    buffer's ``pos % window`` write)."""
    ps = pool.k.shape[1]
    r = pos if window == 0 else pos % window
    lp = jnp.clip(r // ps, 0, pages.shape[1] - 1)
    off = r % ps
    pid = jnp.take_along_axis(pages, lp[:, None], axis=1)[:, 0]   # (B,)
    return PagedKVCache(
        k=pool.k.at[pid, off].set(k_new[:, 0].astype(pool.k.dtype)),
        v=pool.v.at[pid, off].set(v_new[:, 0].astype(pool.v.dtype)))


def _merge_partials(out_a, lse_a, out_b, lse_b):
    """Combine two partial online-softmax results over *disjoint* KV
    sets (the prefix-page gather and the in-flight chunk) into the exact
    softmax over their union — the standard flash-decode LSE merge.
    out: (B,Hq,Sq,hd); lse: (B,Hkv,g,Sq) fp32. A fully-masked partial
    carries lse ≈ -1e30 and drops out with weight 0 (the max-shift keeps
    the other side's weight at exp(0) = 1, so the denominator never
    vanishes)."""
    b, hq, sq, hd = out_a.shape
    hkv, g = lse_a.shape[1], lse_a.shape[2]
    oa = out_a.reshape(b, hkv, g, sq, hd).astype(jnp.float32)
    ob = out_b.reshape(b, hkv, g, sq, hd).astype(jnp.float32)
    m = jnp.maximum(lse_a, lse_b)
    wa = jnp.exp(lse_a - m)
    wb = jnp.exp(lse_b - m)
    out = ((oa * wa[..., None] + ob * wb[..., None])
           / (wa + wb)[..., None])
    return out.reshape(b, hq, sq, hd).astype(out_a.dtype)


def write_chunk_pages(pool: PagedKVCache, k_new, v_new, offset, chunk_len,
                      pages, window: int = 0):
    """Append a prefill chunk's K/V (B, Sc, Hkv, hd) at logical
    positions ``offset .. offset + chunk_len - 1`` through the block
    table ``pages`` (B, n_logical) — the multi-token generalization of
    :func:`write_pages`. ``offset`` and ``chunk_len`` are scalar or
    per-row (B,) int32 — per-row ``chunk_len`` is how the speculative
    verify step writes only each slot's *accepted* draft rows (a row
    with ``chunk_len == 0`` writes nothing). Right padding (rows >=
    chunk_len) routes out of range and is dropped. Windowed layers
    write through the ring (``pos % window``)
    and keep only the chunk's last ``window`` positions — earlier rows
    would be clobbered by a later in-chunk position at the same ring
    slot, and no future query needs them — which also keeps the
    scatter's target indices duplicate-free.

    Shared-page contract (PR 8): every page this scatter can touch —
    logical pages ``offset // ps .. (offset + chunk_len - 1) // ps`` —
    must be slot-private (refcount 1). The engine guarantees it: a
    prefix-cache hit starts the chunk schedule *after* the shared
    pages, and the partially-shared boundary page is remapped by
    :func:`copy_page` (``PagePool.cow``) before the first chunk that
    writes into it."""
    b, sc = k_new.shape[:2]
    ps = pool.k.shape[1]
    i = jnp.arange(sc)
    offset = jnp.broadcast_to(jnp.asarray(offset), (b,))
    clen = jnp.broadcast_to(jnp.asarray(chunk_len), (b,))
    pos = offset[:, None] + i[None]                            # (B, Sc)
    valid = i[None] < clen[:, None]
    r = pos
    if window:
        valid &= pos >= (offset + clen)[:, None] - window
        r = pos % window
    lp = jnp.clip(r // ps, 0, pages.shape[1] - 1)              # (B, Sc)
    pid = jnp.where(valid, jnp.take_along_axis(pages, lp, axis=1),
                    pool.k.shape[0])
    off = r % ps
    return PagedKVCache(
        k=pool.k.at[pid, off].set(k_new.astype(pool.k.dtype),
                                  mode="drop"),
        v=pool.v.at[pid, off].set(v_new.astype(pool.v.dtype),
                                  mode="drop"))


def copy_page(pool: PagedKVCache, src, dst):
    """Copy one physical page's K/V rows ``src`` → ``dst`` (traced int32
    scalars) on the *stored* 5-D leaves (R, P, ps, Hkv, hd) — the
    copy-on-write step before a slot's first write into a shared
    prefix-cache page. ``src == dst`` is the identity (the non-COW
    steady state), so the copy folds into the chunk program as two
    scalar operands instead of a separate compiled unit. Rows past the
    kept prefix carry donor garbage; length masking hides them until
    the slot overwrites them — the same contract scratch pages rely
    on."""
    return PagedKVCache(k=pool.k.at[:, dst].set(pool.k[:, src]),
                        v=pool.v.at[:, dst].set(pool.v[:, src]))


def paged_chunk_apply(params, x, pool: PagedKVCache, *, cfg: ModelConfig,
                      offset, chunk_len, pages, window: int = 0,
                      norm: Optional[ops.NormSpec] = None, residual=None):
    """Chunked-prefill forward for one attention layer: a row panel of
    ``Sc`` prompt tokens starting at absolute position ``offset``
    ((B,) int32, traced), of which the first ``chunk_len`` are real
    (right padding masked). x: (B, Sc, d). Returns (out, new_pool);
    norm/residual as in :func:`apply`.

    Attention is the exact softmax over prefix ∪ chunk, assembled from
    two partials sharing the row-wise ``_online_update`` math:

      * the already-written KV pages, via the multi-query
        :func:`_paged_fwd` prefix gather (per-query window masking,
        ring position recovery for sliding-window layers);
      * the in-flight chunk itself, causally, via :func:`_chunked_fwd`
        in chunk-relative coordinates (the window constraint is
        translation-invariant);

    merged by :func:`_merge_partials`. The chunk's own K/V then append
    at the position offset (:func:`write_chunk_pages`) — strictly after
    the prefix gather, so ring writes cannot clobber prefix keys the
    chunk's queries still need.
    """
    out, k, v = _chunk_attn_core(params, x, pool, cfg=cfg, offset=offset,
                                 chunk_len=chunk_len, pages=pages,
                                 window=window, norm=norm,
                                 residual=residual)
    pool = write_chunk_pages(pool, k, v, offset, chunk_len, pages,
                             window)
    return out, pool


def _chunk_attn_core(params, x, pool: PagedKVCache, *, cfg: ModelConfig,
                     offset, chunk_len, pages, window: int,
                     norm: Optional[ops.NormSpec], residual):
    """Shared math of :func:`paged_chunk_apply` /
    :func:`paged_verify_apply`: exact softmax over prefix ∪ chunk with
    no pool mutation. Returns (projected out, chunk k, chunk v)."""
    b, sc, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = offset[:, None] + jnp.arange(sc, dtype=jnp.int32)[None]
    q, k, v = _project_qkv(params, x, cfg, norm)
    q = q.reshape(b, sc, hq, hd)
    k = k.reshape(b, sc, hkv, hd)
    v = v.reshape(b, sc, hkv, hd)
    q, k = _apply_rope(q, k, cfg, positions)
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    clen = jnp.broadcast_to(jnp.asarray(chunk_len), (b,))
    with jax.named_scope("rowwise_chunk_attn"):
        out_c, lse_c = _chunked_fwd(qh, kh, vh, clen, causal=True,
                                    window=window, q_offset=0, chunk=1024)
        ps = pool.k.shape[1]
        tbl = pages[:, :max(window // ps, 1)] if window else pages
        out_p, lse_p = _paged_fwd(qh, pool.k, pool.v, tbl,
                                  jnp.broadcast_to(jnp.asarray(offset),
                                                   (b,)),
                                  chunk=1024, q_offset=offset,
                                  window=window)
        out = _merge_partials(out_c, lse_c, out_p, lse_p)
    out = out.transpose(0, 2, 1, 3).reshape(b, sc, hq * hd)
    return _out_proj(out, params["wo"], residual), k, v


def paged_verify_apply(params, x, pool: PagedKVCache, *,
                       cfg: ModelConfig, offset, chunk_len, pages,
                       window: int = 0,
                       norm: Optional[ops.NormSpec] = None,
                       residual=None):
    """Speculative-verify forward for one attention layer: bit-identical
    attention math to :func:`paged_chunk_apply` over the draft panel
    (the panel is causal over itself plus the slot's written prefix),
    but the panel's K/V are NOT written to the pool — they are returned
    so the engine can score the logits first and then write only the
    accepted prefix rows (:func:`write_chunk_pages` with per-row
    accepted lengths). Deferring the write keeps rejected drafts out of
    the pool entirely, which matters for sliding-window layers: a ring
    write from a rejected row would clobber the very prefix keys the
    re-decode of that position still needs. Returns (out, (k, v))."""
    out, k, v = _chunk_attn_core(params, x, pool, cfg=cfg, offset=offset,
                                 chunk_len=chunk_len, pages=pages,
                                 window=window, norm=norm,
                                 residual=residual)
    return out, (k, v)


def paged_decode_apply(params, x, pool: PagedKVCache, *, cfg: ModelConfig,
                       lengths, pages, window: int = 0,
                       norm: Optional[ops.NormSpec] = None, residual=None):
    """One-token decode against a paged KV pool. x: (B, 1, d); lengths:
    (B,) tokens already written; pages: (B, max_pages) block table.
    Returns (out, new_pool). norm/residual as in :func:`apply`.

    The attention core is the same online-softmax row-wise primitive as
    the dense path, but each chunk gathers only the slot's live pages —
    idle table entries point at the slot's scratch page and are masked
    by kv_len.
    """
    b = x.shape[0]
    hq, hd = cfg.n_heads, cfg.head_dim
    q, k, v = _decode_qkv(params, x, cfg, lengths, norm)
    pool = write_pages(pool, k, v, lengths, pages, window)
    ps = pool.k.shape[1]
    if window:
        tbl = pages[:, :max(window // ps, 1)]
        kv_len = jnp.minimum(lengths + 1, window)
    else:
        tbl = pages
        kv_len = lengths + 1
    qh = q.transpose(0, 2, 1, 3)
    out = chunked_attention(qh, pool.k, pool.v, causal=False, window=0,
                            kv_len=kv_len, pages=tbl)
    out = out.reshape(b, 1, hq * hd)
    return _out_proj(out, params["wo"], residual), pool


def decode_apply(params, x, cache: KVCache, *, cfg: ModelConfig,
                 lengths, window: int = 0,
                 norm: Optional[ops.NormSpec] = None, residual=None):
    """One-token decode. x: (B, 1, d); lengths: (B,) tokens already in
    cache. Returns (out, new_cache). norm/residual as in :func:`apply`.

    Global (non-window) layers use the sequence-sharded flash decode
    when the cache is sharded along seq over 'model' and the
    'decode_attn' rule is 'sharded' — partial per-shard softmax combined
    with a log-sum-exp psum, so the cache is never gathered.
    """
    from repro.core import partitioning
    b, _, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _decode_qkv(params, x, cfg, lengths, norm)

    mesh = partitioning.active_mesh()
    use_sharded = (
        window == 0 and mesh is not None
        and "model" in mesh.axis_names
        and partitioning.get_rules().get("decode_attn") == "sharded"
        and partitioning.get_rules().get("kv_seq") == "model"
        and cache.k.shape[1] % dict(zip(
            mesh.axis_names, mesh.devices.shape))["model"] == 0)
    if use_sharded:
        out, cache = _decode_seq_sharded(q, k, v, cache, lengths,
                                         cfg=cfg, mesh=mesh)
        out = out.reshape(b, 1, hq * hd)
        return ops.matmul(out, params["wo"], residual=residual), cache

    cache = write_cache(cache, k, v, lengths, window)
    alloc = cache.k.shape[1]
    kh = cache.k.transpose(0, 2, 1, 3)
    vh = cache.v.transpose(0, 2, 1, 3)
    qh = q.transpose(0, 2, 1, 3)

    if window and window <= alloc:
        # Ring buffer holds exactly the last `window` tokens; every valid
        # entry attends (causality is implied by what was written).
        kv_len = jnp.minimum(lengths + 1, alloc)
    else:
        kv_len = lengths + 1
    out = chunked_attention(qh, kh, vh, causal=False, window=0,
                            q_offset=0, kv_len=kv_len)
    out = out.reshape(b, 1, hq * hd)
    return _out_proj(out, params["wo"], residual), cache


def _decode_seq_sharded(q, k_new, v_new, cache: KVCache, lengths, *,
                        cfg: ModelConfig, mesh):
    """Flash-decode with the KV cache sharded along sequence over
    'model': each shard writes/attends its local chunk; partial
    (m, l, acc) combine via pmax/psum of O(B x H x hd) — the cache is
    never all-gathered. Beyond-paper optimization (see EXPERIMENTS §Perf).
    """
    from repro.core import partitioning
    b, _, hq, hd = q.shape
    hkv = cfg.n_kv_heads
    g = hq // hkv
    s_alloc = cache.k.shape[1]
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    s_loc = s_alloc // n_model
    scale = hd ** -0.5

    r = partitioning.resolve
    cache_spec = r(("batch", "kv_seq", "kv_heads", None), mesh,
                   shape=cache.k.shape)
    q_spec = r(("batch", "kv_heads", None, None), mesh,
               shape=(b, hq, 1, hd))
    new_spec = r(("batch", None, "kv_heads", None), mesh,
                 shape=k_new.shape)
    len_spec = r(("batch",), mesh, shape=lengths.shape)

    def body(qb, knb, vnb, kc, vc, lens):
        bl = qb.shape[0]
        shard = jax.lax.axis_index("model")
        base = shard * s_loc
        # write the new token's K/V if its slot lives on this shard
        pos = lens                                    # (B,) absolute
        lpos = jnp.clip(pos - base, 0, s_loc - 1)
        here = (pos >= base) & (pos < base + s_loc)   # (B,)
        bidx = jnp.arange(bl)
        upd_k = kc.at[bidx, lpos].set(
            jnp.where(here[:, None, None], knb[:, 0].astype(kc.dtype),
                      kc[bidx, lpos]))
        upd_v = vc.at[bidx, lpos].set(
            jnp.where(here[:, None, None], vnb[:, 0].astype(vc.dtype),
                      vc[bidx, lpos]))
        # local partial attention (single query row)
        hkv_l = upd_k.shape[2]
        qg = qb.reshape(bl, hkv_l, g, hd).astype(jnp.float32)
        kh = upd_k.transpose(0, 2, 1, 3).astype(jnp.float32)
        vh = upd_v.transpose(0, 2, 1, 3).astype(jnp.float32)
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, kh) * scale
        k_pos = base + jnp.arange(s_loc)
        valid = k_pos[None] < (lens + 1)[:, None]     # (B, s_loc)
        s = jnp.where(valid[:, None, None], s, -1e30)
        m_i = jnp.max(s, -1)                          # (B, hkv, g)
        p = jnp.where(valid[:, None, None], jnp.exp(s - m_i[..., None]),
                      0.0)
        l_i = jnp.sum(p, -1)
        acc_i = jnp.einsum("bhgk,bhkd->bhgd", p, vh)
        # LSE combine across shards: tiny psums instead of a cache gather
        m = jax.lax.pmax(m_i, "model")
        alpha = jnp.exp(m_i - m)
        l_tot = jax.lax.psum(l_i * alpha, "model")
        acc = jax.lax.psum(acc_i * alpha[..., None], "model")
        out = acc / jnp.maximum(l_tot, 1e-30)[..., None]
        out = out.reshape(bl, 1, hkv_l * g * hd)
        # pin cache dtype: an f32 leak here makes the layer scan convert
        # the WHOLE stacked cache f32<->bf16 every iteration
        return (out.astype(qb.dtype), upd_k.astype(kc.dtype),
                upd_v.astype(vc.dtype))

    out_spec = r(("batch", None, "kv_heads"), mesh,
                 shape=(b, 1, hq * hd))
    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, new_spec, new_spec, cache_spec, cache_spec,
                  len_spec),
        out_specs=(out_spec, cache_spec, cache_spec),
        check_vma=False)
    out, new_k, new_v = fn(q.transpose(0, 2, 1, 3), k_new, v_new,
                           cache.k, cache.v, lengths)
    return out, KVCache(k=new_k, v=new_v)
