"""Stage-compiled language model: init / train forward / prefill / decode.

Layers are *scan-stacked*: per stage, parameters carry a leading repeat
dim and a single ``lax.scan`` executes the whole stage, so HLO size (and
compile time for the 512-device dry-run) is depth-independent.
Heterogeneous stacks (gemma3's 5-local:1-global, zamba2's mamba+shared-
attention) scan over super-block bodies; zamba2's shared block params are
closed over instead of stacked (single weight copy, per the Zamba2
design).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core import partitioning as part
from repro.core.partitioning import logical_constraint
from repro.core.types import ModelConfig, Stage
from repro.kernels import ops
from repro.models import attention, blocks, mamba2, rope
from repro.models.attention import KVCache

# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab rounded up to a shardable multiple (MaxText-style padding);
    the pad columns are masked to -inf in the logits."""
    return -(-cfg.vocab // 256) * 256


def _init_stage(key, stage: Stage, cfg: ModelConfig, dtype):
    stacked_p, stacked_s, shared_p, shared_s = {}, {}, {}, {}
    for i, blk in enumerate(stage.body):
        k = jax.random.fold_in(key, i)
        stack = None if blk.shared else stage.repeat
        p, s = blocks.init_block(k, blk, cfg, stack, dtype)
        if blk.shared:
            shared_p[str(i)], shared_s[str(i)] = p, s
        else:
            stacked_p[str(i)], stacked_s[str(i)] = p, s
    return ({"stacked": stacked_p, "shared": shared_p},
            {"stacked": stacked_s, "shared": shared_s})


def init_lm(key, cfg: ModelConfig, dtype=None):
    """Returns (params, logical_specs) with identical tree structure."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    vp = padded_vocab(cfg)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (vp, d), jnp.float32)
                  * 0.02).astype(dtype),
    }
    specs: Dict[str, Any] = {"embed": ("vocab", "embed")}
    params["stages"], specs["stages"] = [], []
    for si, stage in enumerate(cfg.stages()):
        p, s = _init_stage(jax.random.fold_in(ks[1], si), stage, cfg, dtype)
        params["stages"].append(p)
        specs["stages"].append(s)
    params["final_norm"] = {"g": jnp.ones((d,), dtype)}
    specs["final_norm"] = {"g": (None,)}
    if cfg.norm == "layer":
        params["final_norm"]["b"] = jnp.zeros((d,), dtype)
        specs["final_norm"]["b"] = (None,)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(ks[2], (d, vp), jnp.float32)
                             / math.sqrt(d)).astype(dtype)
        specs["lm_head"] = ("embed", "vocab")
    if cfg.encdec:
        enc_p, enc_s = [], []
        for si, stage in enumerate(cfg.enc_stages()):
            p, s = _init_stage(jax.random.fold_in(ks[3], si), stage, cfg,
                               dtype)
            enc_p.append(p)
            enc_s.append(s)
        fin_p = {"g": jnp.ones((d,), dtype)}
        fin_s = {"g": (None,)}
        if cfg.norm == "layer":
            fin_p["b"] = jnp.zeros((d,), dtype)
            fin_s["b"] = (None,)
        params["enc"] = {"stages": enc_p, "final_norm": fin_p}
        specs["enc"] = {"stages": enc_s, "final_norm": fin_s}
    return params, specs


# ----------------------------------------------------------------------
# Param-layout migration: fused (wqkv / wgi) <-> seed (wq/wk/wv, wg/wi)
# ----------------------------------------------------------------------


def _cat_leaves(leaves):
    """Concatenate sibling projection leaves along the output axis.
    Weight-only int8 leaves fuse exactly: per-output-channel scales are
    per-column, so the fused panel's scales ARE the concatenated parts'
    scales (see quant.quantize_tree)."""
    if quant.is_quantized(leaves[0]):
        return {"q": jnp.concatenate([l["q"] for l in leaves], axis=-1),
                "s": jnp.concatenate([l["s"] for l in leaves], axis=-1)}
    return jnp.concatenate(leaves, axis=-1)


def _split_leaf(leaf, widths):
    """Inverse of :func:`_cat_leaves`."""
    cuts = list(np.cumsum(widths)[:-1])
    if quant.is_quantized(leaf):
        qs = jnp.split(leaf["q"], cuts, axis=-1)
        ss = jnp.split(leaf["s"], cuts, axis=-1)
        return [{"q": q, "s": s} for q, s in zip(qs, ss)]
    return jnp.split(leaf, cuts, axis=-1)


def _migrate_blocks(cfg: ModelConfig, params, block_fn):
    """Apply ``block_fn(blk, block_params) -> block_params`` to every
    block's param dict (stacked and shared groups, decoder and encoder
    stages); returns a new tree, every other leaf untouched."""
    def stage_list(stages_cfg, stages_p):
        new = []
        for stage, sp in zip(stages_cfg, stages_p):
            ns = {"stacked": dict(sp["stacked"]),
                  "shared": dict(sp["shared"])}
            for i, blk in enumerate(stage.body):
                key = str(i)
                group = "shared" if blk.shared else "stacked"
                if key in ns[group]:
                    ns[group][key] = block_fn(blk, ns[group][key])
            new.append(ns)
        return new

    out = dict(params)
    out["stages"] = stage_list(cfg.stages(), params["stages"])
    if cfg.encdec and "enc" in params:
        enc = dict(params["enc"])
        enc["stages"] = stage_list(cfg.enc_stages(),
                                   params["enc"]["stages"])
        out["enc"] = enc
    return out


def fuse_params(cfg: ModelConfig, params):
    """Migrate a seed-layout param tree (split wq/wk/wv, wg/wi leaves —
    PRs 0–3, old checkpoints) to the fused layout ``init_lm`` now
    produces: one ``wqkv`` leaf per self-attention layer, one ``wkv``
    per cross-attention layer, one ``wgi`` per gated MLP. Idempotent;
    exact (pure concatenation, also for weight-only int8 leaves and for
    per-leaf optimizer moments — see ``train.step.fuse_state``)."""
    def block_fn(blk, p):
        p = dict(p)
        if blk.mixer == "attn" and "attn" in p and "wq" in p["attn"]:
            a = dict(p["attn"])
            a["wqkv"] = _cat_leaves([a.pop("wq"), a.pop("wk"),
                                     a.pop("wv")])
            p["attn"] = a
        if blk.cross_attn and "cross" in p and "wk" in p["cross"]:
            c = dict(p["cross"])
            c["wkv"] = _cat_leaves([c.pop("wk"), c.pop("wv")])
            p["cross"] = c
        if (blk.ffn == "mlp" and "ffn" in p and "wg" in p["ffn"]
                and "wi" in p["ffn"]):
            f = dict(p["ffn"])
            f["wgi"] = _cat_leaves([f.pop("wg"), f.pop("wi")])
            p["ffn"] = f
        return p

    return _migrate_blocks(cfg, params, block_fn)


def unfuse_params(cfg: ModelConfig, params):
    """Inverse of :func:`fuse_params`: recover the seed's split layout
    (e.g. to restore INTO an old checkpoint's tree structure, or to
    export one). ``fuse_params(cfg, unfuse_params(cfg, p))`` is the
    identity."""
    qo, kvo, _ = attention.proj_splits(cfg)

    def block_fn(blk, p):
        p = dict(p)
        if blk.mixer == "attn" and "attn" in p and "wqkv" in p["attn"]:
            a = dict(p["attn"])
            a["wq"], a["wk"], a["wv"] = _split_leaf(a.pop("wqkv"),
                                                    (qo, kvo, kvo))
            p["attn"] = a
        if blk.cross_attn and "cross" in p and "wkv" in p["cross"]:
            c = dict(p["cross"])
            c["wk"], c["wv"] = _split_leaf(c.pop("wkv"), (kvo, kvo))
            p["cross"] = c
        if blk.ffn == "mlp" and "ffn" in p and "wgi" in p["ffn"]:
            f = dict(p["ffn"])
            wgi = f.pop("wgi")
            half = (wgi["q"] if quant.is_quantized(wgi)
                    else wgi).shape[-1] // 2
            f["wg"], f["wi"] = _split_leaf(wgi, (half, half))
            p["ffn"] = f
        return p

    return _migrate_blocks(cfg, params, block_fn)


# ----------------------------------------------------------------------
# Stage execution
# ----------------------------------------------------------------------


def _run_stage(stage: Stage, sp, x, *, cfg: ModelConfig, mode: str,
               positions=None, lengths=None, cache=None, enc_out=None,
               pages=None, chunk_len=None, causal=True, remat=False):
    """Scan a stage. Returns (x, aux, new_cache_or_prefill_states).
    ``pages`` (the serving block table) is scan-invariant: every layer
    indexes its own pool through the same per-slot table."""
    stacked, shared = sp["stacked"], sp["shared"]

    def body(carry, xs):
        x, aux = carry
        sliced, cache_slice = xs
        out_states = {}
        for i, blk in enumerate(stage.body):
            key = str(i)
            bp = sliced[key] if key in sliced else shared[key]
            csl = cache_slice.get(key) if cache_slice else None
            x, io = blocks.apply_block(
                blk, bp, x, cfg=cfg, mode=mode, positions=positions,
                lengths=lengths, cache=csl, enc_out=enc_out, pages=pages,
                chunk_len=chunk_len,
                window_override=None if causal else 0)
            aux = aux + io.aux
            if mode in ("decode", "chunk") and io.new_cache is not None:
                out_states[key] = io.new_cache
            elif (mode in ("prefill", "verify")
                    and io.prefill_state is not None):
                out_states[key] = io.prefill_state
        return (x, aux), out_states

    if remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (stacked, cache) if cache is not None else (stacked, {})
    (x, aux), states = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    xs, length=stage.repeat)
    return x, aux, states


def _run_stages(stage_params, stages, x, *, cache=None, **kw):
    aux_total = jnp.zeros((), jnp.float32)
    all_states = []
    for si, (stage, sp) in enumerate(zip(stages, stage_params)):
        stage_cache = cache[si] if cache is not None else None
        x, aux, states = _run_stage(stage, sp, x, cache=stage_cache, **kw)
        aux_total = aux_total + aux
        all_states.append(states)
    return x, aux_total, all_states


# ----------------------------------------------------------------------
# Embedding / logits
# ----------------------------------------------------------------------


def embed(params, tokens, cfg: ModelConfig, extra: Optional[dict] = None):
    w = params["embed"]
    if quant.is_quantized(w):
        # weight-only int8 tree: gather int8 rows, then dequantize only
        # the gathered (B, S, d) block by the per-column scales
        x = (jnp.take(w["q"], tokens, axis=0).astype(jnp.float32)
             * w["s"]).astype(jnp.dtype(cfg.dtype))
    else:
        x = jnp.take(w, tokens, axis=0)
    if cfg.frontend == "vision" and extra and "vis_embeds" in extra:
        ve = extra["vis_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, ve, (0, 0, 0))
    return x


def unembed(params, x, cfg: ModelConfig):
    x = ops.layernorm(x, params["final_norm"]["g"],
                      params["final_norm"].get("b"), kind=cfg.norm)
    tp = part.tp_axis()
    if cfg.tie_embeddings:
        # tied embeddings stay replicated under TP (the embed gather
        # needs every row anyway), so the logits are already full-width
        w = quant.resolve_weight(params["embed"], x.dtype).T
        logits = ops.matmul(x, w, out_dtype=jnp.float32)
    elif tp is not None:
        # vocab-sharded lm_head: each shard computes its contiguous
        # logit block exactly (pure N-split, bitwise identical columns),
        # then a tiled all-gather rebuilds the full row — the pad mask
        # below must see GLOBAL column indices, hence gather-first
        logits = jax.lax.all_gather(
            ops.matmul(x, params["lm_head"], out_dtype=jnp.float32),
            tp, axis=x.ndim - 1, tiled=True)
    else:
        logits = ops.matmul(x, params["lm_head"], out_dtype=jnp.float32)
    vp = padded_vocab(cfg)
    if vp != cfg.vocab:  # mask pad columns out of the softmax
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < cfg.vocab, logits, -1e30)
    return logical_constraint(logits, "batch", "seq", "vocab_act")


def _positions(cfg: ModelConfig, tokens, extra):
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.rope == "mrope" and extra and "positions3" in extra:
        return extra["positions3"]
    return pos


def encode(params, frames, cfg: ModelConfig):
    """Whisper encoder: precomputed frame embeddings (B, T, d)."""
    x = frames + rope.sinusoidal_embedding(
        frames.shape[1], cfg.d_model).astype(frames.dtype)[None]
    x, _, _ = _run_stages(params["enc"]["stages"], cfg.enc_stages(), x,
                          cfg=cfg, mode="train", positions=None,
                          causal=False, remat=True)
    fn = params["enc"]["final_norm"]
    return ops.layernorm(x, fn["g"], fn.get("b"), kind=cfg.norm)


def forward(params, tokens, cfg: ModelConfig, *,
            extra: Optional[dict] = None, remat: bool = True):
    """Full train-mode forward -> (logits, aux_loss)."""
    x = embed(params, tokens, cfg, extra)
    x = logical_constraint(x, "batch", "seq", "act_embed")
    if cfg.rope == "none" and not cfg.encdec:
        x = x + rope.sinusoidal_embedding(
            x.shape[1], cfg.d_model).astype(x.dtype)[None]
    enc_out = None
    if cfg.encdec:
        assert extra is not None and "frames" in extra
        enc_out = encode(params, extra["frames"], cfg)
        x = x + rope.sinusoidal_embedding(
            x.shape[1], cfg.d_model).astype(x.dtype)[None]
    positions = _positions(cfg, tokens, extra)
    x, aux, _ = _run_stages(params["stages"], cfg.stages(), x, cfg=cfg,
                            mode="train", positions=positions,
                            enc_out=enc_out, remat=remat)
    return unembed(params, x, cfg), aux


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = True):
    """Cross-entropy next-token loss -> (loss, metrics)."""
    logits, aux = forward(params, batch["tokens"], cfg,
                          extra={k: v for k, v in batch.items()
                                 if k not in ("tokens", "labels")} or None,
                          remat=remat)
    labels = batch["labels"]
    valid = (labels >= 0)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * valid
    ntok = jnp.maximum(valid.sum(), 1)
    loss = nll.sum() / ntok
    metrics = {"loss": loss, "aux_loss": aux, "ntokens": ntok,
               "accuracy": ((jnp.argmax(logits, -1) == safe) * valid
                            ).sum() / ntok}
    return loss + aux, metrics


# ----------------------------------------------------------------------
# KV / SSM cache: init, specs, prefill conversion
# ----------------------------------------------------------------------


def _slot_cache_init(blk, cfg: ModelConfig, repeat, batch, alloc, dtype,
                     pool=None):
    c = {}
    if blk.mixer == "attn":
        if pool is not None:
            # paged serving: (R, n_pages + n_slots scratch, ps, Hkv, hd)
            n_pages, ps = pool
            shape = (repeat, n_pages + batch, ps, cfg.n_kv_heads,
                     cfg.head_dim)
            c["kv"] = attention.PagedKVCache(k=jnp.zeros(shape, dtype),
                                             v=jnp.zeros(shape, dtype))
        else:
            w = blk.window
            s_alloc = min(alloc, w) if w else alloc
            shape = (repeat, batch, s_alloc, cfg.n_kv_heads, cfg.head_dim)
            c["kv"] = KVCache(k=jnp.zeros(shape, dtype),
                              v=jnp.zeros(shape, dtype))
    elif blk.mixer == "mamba2":
        st = mamba2.init_state(cfg, batch, dtype)
        c["mamba"] = jax.tree.map(
            lambda a: jnp.zeros((repeat,) + a.shape, a.dtype), st)
    elif blk.mixer == "rwkv6":
        r = cfg.rwkv
        h = cfg.d_model // r.head_dim
        c["rwkv_t"] = {
            "x_prev_t": jnp.zeros((repeat, batch, cfg.d_model), dtype),
            "wkv": jnp.zeros((repeat, batch, h, r.head_dim, r.head_dim),
                             jnp.float32)}
    if blk.cross_attn:
        shape = (repeat, batch, cfg.cross_len, cfg.n_kv_heads, cfg.head_dim)
        c["cross_kv"] = (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if blk.ffn == "rwkv6_cmix":
        c["rwkv_c"] = {"x_prev_c": jnp.zeros((repeat, batch, cfg.d_model),
                                             dtype)}
    return c


def _init_cache_tree(cfg: ModelConfig, batch, alloc, dtype, pool=None):
    out = []
    for stage in cfg.stages():
        sc = {}
        for i, blk in enumerate(stage.body):
            c = _slot_cache_init(blk, cfg, stage.repeat, batch, alloc,
                                 dtype, pool=pool)
            if c:
                sc[str(i)] = c
        out.append(sc)
    return out


def init_cache(cfg: ModelConfig, batch: int, alloc: int, dtype=None):
    """Zeroed cache for standalone decode (the decode dry-run cells)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    return _init_cache_tree(cfg, batch, alloc, dtype)


def init_paged_cache(cfg: ModelConfig, n_slots: int, max_len: int, *,
                     page_size: int = 16, n_pages: int = 0, dtype=None):
    """Serving cache with paged attention KV: every attention layer gets
    a page pool ``(R, n_pages + n_slots, page_size, Hkv, hd)`` indexed
    by the engine's block tables (the ``+ n_slots`` are per-slot
    *scratch* pages idle and mid-prefill slots write to — private rows,
    so lockstep writes from idle slots never serialize on one shared
    page); recurrent / cross-attention state stays per-slot dense.

    ``n_pages == 0`` sizes the pool for full occupancy
    (``n_slots * ceil(max_len / page_size)`` real pages); pass less to
    oversubscribe. Sliding windows must be page-aligned
    (``window % page_size == 0``) so ring pages tile exactly.
    """
    dtype = dtype or jnp.dtype(cfg.dtype)
    max_pages = -(-max_len // page_size)
    n_pages = n_pages or n_slots * max_pages
    for stage in cfg.stages():
        for blk in stage.body:
            if blk.mixer == "attn" and blk.window:
                assert blk.window % page_size == 0, (
                    f"sliding window {blk.window} must be a multiple of "
                    f"page_size {page_size}")
    return _init_cache_tree(cfg, n_slots, max_len, dtype,
                            pool=(n_pages, page_size))


def cache_logical_specs(cache):
    """Logical sharding names for every cache leaf (layer, batch, seq...).
    Dense caches only — paged pools are engine-local (single host)."""
    def spec(leaf):
        names = [None] * leaf.ndim
        names[0] = "layers"
        if leaf.ndim >= 2:
            names[1] = "batch"
        if leaf.ndim == 5:           # (R, B, S, kv_heads, hd)
            names[2] = "kv_seq"
            names[3] = "kv_heads"
        return tuple(names)

    return jax.tree.map(spec, cache)


def _ring_from_prefill(k, window):
    """Convert stacked prefill states (R,B,S,H,hd) to a ring buffer of
    size `window` holding the last `window` tokens at slots p % window."""
    s = k.shape[2]
    if s <= window:
        pad = [(0, 0)] * k.ndim
        pad[2] = (0, window - s)
        return jnp.pad(k, pad)
    p = jnp.arange(s - window, s)
    order = jnp.argsort(p % window)
    return jnp.take(k, p[order], axis=2)


def states_to_cache(cfg: ModelConfig, all_states, alloc: int):
    """Prefill scan outputs -> decode cache (pads KV to alloc)."""
    out = []
    for stage, states in zip(cfg.stages(), all_states):
        sc = {}
        for i, blk in enumerate(stage.body):
            st = states.get(str(i))
            if st is None:
                continue
            c = {}
            if "kv" in st:
                k, v = st["kv"]
                if blk.window:
                    k = _ring_from_prefill(k, blk.window)
                    v = _ring_from_prefill(v, blk.window)
                else:
                    pad = [(0, 0)] * k.ndim
                    pad[2] = (0, alloc - k.shape[2])
                    k, v = jnp.pad(k, pad), jnp.pad(v, pad)
                c["kv"] = KVCache(k=k, v=v)
            if "mamba" in st:
                c["mamba"] = st["mamba"]
            if "rwkv_t" in st:
                c["rwkv_t"] = st["rwkv_t"]
            if "rwkv_c" in st:
                c["rwkv_c"] = st["rwkv_c"]
            if "cross_kv" in st:
                c["cross_kv"] = st["cross_kv"]
            sc[str(i)] = c
        out.append(sc)
    return out


def prefill_states(params, tokens, cfg: ModelConfig, *,
                   extra: Optional[dict] = None, last_pos=None):
    """Full-sequence prefill -> (logits, raw per-layer scan states).

    ``last_pos`` ((B,) int32) supports *bucketed* prefill: tokens are
    right-padded to a static bucket length and the logits are gathered
    at position ``last_pos - 1`` (the last real token). Causal attention
    keeps every real position's activations and KV states untouched by
    the tail padding; the pad tokens' own KV is dropped downstream by
    the block-table length bookkeeping. Recurrent mixers (mamba/rwkv)
    fold padding into their state, so recurrent archs must prefill at
    exact lengths (``last_pos=None``).
    """
    b, s = tokens.shape
    x = embed(params, tokens, cfg, extra)
    x = logical_constraint(x, "batch", "seq", "act_embed")
    if cfg.rope == "none" and not cfg.encdec:
        x = x + rope.sinusoidal_embedding(s, cfg.d_model).astype(
            x.dtype)[None]
    enc_out = None
    if cfg.encdec:
        enc_out = encode(params, extra["frames"], cfg)
        x = x + rope.sinusoidal_embedding(s, cfg.d_model).astype(
            x.dtype)[None]
    positions = _positions(cfg, tokens, extra)
    x, _, states = _run_stages(params["stages"], cfg.stages(), x, cfg=cfg,
                               mode="prefill", positions=positions,
                               enc_out=enc_out, remat=False)
    if last_pos is None:
        xl = x[:, -1:]
    else:
        idx = (jnp.asarray(last_pos, jnp.int32) - 1)[:, None, None]
        xl = jnp.take_along_axis(
            x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    logits = unembed(params, xl, cfg)
    return logits[:, 0], states


def prefill(params, tokens, cfg: ModelConfig, *,
            extra: Optional[dict] = None, alloc: Optional[int] = None):
    """Full-sequence prefill -> (last-position logits, dense cache)."""
    logits, states = prefill_states(params, tokens, cfg, extra=extra)
    return logits, states_to_cache(cfg, states, alloc or tokens.shape[1])


# ----------------------------------------------------------------------
# Paged prefill insert (the serving engine's slot-admission write)
# ----------------------------------------------------------------------


def _insert_slot(dst, src, slot):
    """Write a (R, 1, ...) prefill state into batch row ``slot`` of a
    (R, B, ...) per-slot cache leaf."""
    starts = (0, slot) + (0,) * (dst.ndim - 2)
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), starts)


def _insert_pages(pool, k, v, *, pages, plen, window, page_size):
    """Scatter prefilled KV states (R, 1, S_pad, Hkv, hd) into the
    slot's pages. Positions >= plen (padding) and, for windowed layers,
    < plen - window (evicted from the ring) route out of range and are
    dropped; stale rows left in a partial tail page are masked at read
    time by the kv_len bookkeeping."""
    ps = page_size
    s_pad = k.shape[2]
    p = jnp.arange(s_pad)
    valid = p < plen
    r = p
    if window:
        valid = valid & (p >= plen - window)
        r = p % window
    lp = jnp.clip(r // ps, 0, pages.shape[0] - 1)
    pid = jnp.where(valid, pages[lp], pool.k.shape[1])   # OOB => dropped
    off = r % ps
    new_k = pool.k.at[:, pid, off].set(
        k[:, 0].astype(pool.k.dtype), mode="drop")
    new_v = pool.v.at[:, pid, off].set(
        v[:, 0].astype(pool.v.dtype), mode="drop")
    return attention.PagedKVCache(k=new_k, v=new_v)


def insert_prefill(cfg: ModelConfig, cache, states, *, slot, pages, plen,
                   page_size: int):
    """Insert a single-request prefill into a paged serving cache: the
    explicit replacement for the old shape-guessing ``_scatter_slot``
    tree-map. Attention KV states scatter into the pages the engine
    granted the slot (``pages``: (max_pages,) physical ids); recurrent /
    cross-attention state writes batch row ``slot``. ``slot`` and
    ``plen`` may be traced scalars, so one compiled program serves every
    slot at a given bucket length.

    Shared-page contract (PR 8): one-shot prefill scatters the *whole*
    prompt, so the engine only routes through here on a prefix-cache
    miss — every granted page is slot-private (refcount 1). Cache hits
    take the chunked path, which starts past the shared pages."""
    out = []
    for si, stage in enumerate(cfg.stages()):
        sc = {}
        for i, blk in enumerate(stage.body):
            key = str(i)
            cur = (cache[si] or {}).get(key)
            if cur is None:
                continue
            st = (states[si] or {}).get(key) or {}
            c = dict(cur)
            if "kv" in st:
                k, v = st["kv"]
                c["kv"] = _insert_pages(cur["kv"], k, v, pages=pages,
                                        plen=plen, window=blk.window,
                                        page_size=page_size)
            for name in ("mamba", "rwkv_t", "rwkv_c", "cross_kv"):
                if name in st:
                    c[name] = jax.tree.map(
                        lambda d, s: _insert_slot(d, s, slot),
                        cur[name], st[name])
            sc[key] = c
        out.append(sc)
    return out


def prefill_chunk(params, cache, tokens, cfg: ModelConfig, *, offset,
                  chunk_len, pages):
    """Chunked-prefill step: one ``prefill_states``-style forward over a
    row panel of the prompt, resumable across engine steps.

    tokens: (1, Sc_pad) — a chunk of a longer prompt starting at
    absolute position ``offset`` (traced scalar; tokens already in the
    paged cache), right-padded to a static chunk shape with the true
    length in ``chunk_len`` (traced, <= Sc_pad). Every attention layer
    attends the slot's already-written KV pages plus the in-flight
    chunk (``attention.paged_chunk_apply``) and appends the chunk's KV
    at the position offset, so successive calls rebuild exactly the KV
    state one-shot prefill + ``insert_prefill`` would have written.
    Returns (next-token logits (1, V) at chunk position chunk_len - 1,
    new_cache). Only causal-attention archs may chunk (the engine gates
    on ``paging.supports_bucketing``); the final chunk's logits are the
    prompt's first-token logits.
    """
    b, s = tokens.shape
    offset = jnp.asarray(offset, jnp.int32)
    x = embed(params, tokens, cfg, None)
    x = logical_constraint(x, "batch", "seq", "act_embed")
    if cfg.rope == "none" and not cfg.encdec:
        pe = rope.sinusoidal_embedding(1 << 16, cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(pe, offset, s,
                                             axis=0)[None].astype(x.dtype)
    lengths = jnp.broadcast_to(offset, (b,))
    x, _, new_cache = _run_stages(params["stages"], cfg.stages(), x,
                                  cfg=cfg, mode="chunk", positions=None,
                                  lengths=lengths, cache=cache,
                                  pages=pages, chunk_len=chunk_len,
                                  remat=False)
    idx = (jnp.asarray(chunk_len, jnp.int32) - 1)[None, None, None]
    xl = jnp.take_along_axis(
        x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    logits = unembed(params, xl, cfg)
    return logits[:, 0], new_cache


def verify_states(params, cache, tokens, cfg: ModelConfig, *, offset,
                  chunk_len, pages):
    """Speculative-verify forward (the batched, read-only sibling of
    :func:`prefill_chunk`): score a (B, Sc) panel — each slot's last
    committed token plus its draft tokens, right-padded to the static
    ladder width — against the paged cache, WITHOUT writing the panel's
    KV. ``offset``/``chunk_len``: per-row (B,) int32 (tokens already in
    the cache / real panel rows, ``1 + k_b``; 0 rows are fully masked).
    Returns (full panel logits (B, Sc, V), per-layer panel KV states) —
    logits, not a gathered position, because acceptance needs every
    panel position's distribution; the caller then writes only accepted
    rows via :func:`insert_verify`. The split mirrors the
    ``prefill_states`` / ``insert_prefill`` pair: forward first, commit
    separately. Only causal-attention archs verify (the engine gates on
    ``paging.supports_bucketing``)."""
    b, s = tokens.shape
    offset = jnp.broadcast_to(jnp.asarray(offset, jnp.int32), (b,))
    x = embed(params, tokens, cfg, None)
    x = logical_constraint(x, "batch", "seq", "act_embed")
    if cfg.rope == "none" and not cfg.encdec:
        pe = rope.sinusoidal_embedding(1 << 16, cfg.d_model)
        pos = offset[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
        x = x + pe[pos].astype(x.dtype)
    x, _, states = _run_stages(params["stages"], cfg.stages(), x,
                               cfg=cfg, mode="verify", positions=None,
                               lengths=offset, cache=cache, pages=pages,
                               chunk_len=chunk_len, remat=False)
    return unembed(params, x, cfg), states


def insert_verify(cfg: ModelConfig, cache, states, *, pages, offset,
                  n_keep):
    """Write the accepted prefix of a verify panel into the paged cache:
    every attention layer scatters its panel rows ``< n_keep[b]`` (per
    row: the re-scored committed token plus the accepted drafts;
    ``n_keep == 0`` writes nothing — inactive or fully-rolled-back
    slots). The layer walk mirrors :func:`insert_prefill`; verify
    states only ever hold attention KV (verify requires a
    bucketing-capable, attention-only arch). The per-layer scatter is
    :func:`attention.write_chunk_pages` vmapped over the scan-stacked
    layer axis, so accepted writes reuse the chunked-prefill scatter
    (including windowed ring routing) exactly."""
    out = []
    for si, stage in enumerate(cfg.stages()):
        sc = {}
        for i, blk in enumerate(stage.body):
            key = str(i)
            cur = (cache[si] or {}).get(key)
            if cur is None:
                continue
            st = (states[si] or {}).get(key) or {}
            c = dict(cur)
            if "kv" in st:
                k, v = st["kv"]

                def wr(pk, pv, kk, vv, window=blk.window):
                    pool = attention.write_chunk_pages(
                        attention.PagedKVCache(k=pk, v=pv), kk, vv,
                        offset, n_keep, pages, window)
                    return pool.k, pool.v

                nk, nv = jax.vmap(wr)(cur["kv"].k, cur["kv"].v, k, v)
                c["kv"] = attention.PagedKVCache(k=nk, v=nv)
            sc[key] = c
        out.append(sc)
    return out


def cow_copy(cache, src, dst):
    """Copy-on-write page copy across every paged attention layer:
    physical page ``src``'s K/V rows land in page ``dst`` (traced int32
    scalars; see :func:`attention.copy_page`). ``src == dst`` is the
    identity, which is how the engine folds the copy into every chunk
    step — non-COW chunks pass ``(0, 0)`` and compile the same program.
    Non-attention state (recurrent, cross-KV) is untouched."""
    return jax.tree.map(
        lambda c: (attention.copy_page(c, src, dst)
                   if isinstance(c, attention.PagedKVCache) else c),
        cache,
        is_leaf=lambda c: isinstance(c, attention.PagedKVCache))


def decode_step(params, cache, tokens, lengths, cfg: ModelConfig,
                pages=None):
    """One decode step. tokens: (B, 1); lengths: (B,) tokens in cache.
    Returns (logits (B, vocab), new_cache). ``pages`` ((B, max_pages)
    int32 block tables) is required when ``cache`` holds paged KV pools
    (see :func:`init_paged_cache`); every layer indexes its own pool
    through the same table."""
    x = embed(params, tokens, cfg, None)
    if cfg.rope == "none" or cfg.encdec:
        pe = rope.sinusoidal_embedding(1 << 16, cfg.d_model)
        x = x + pe[lengths][:, None].astype(x.dtype)
    x, _, new_cache = _run_stages(params["stages"], cfg.stages(), x,
                                  cfg=cfg, mode="decode", positions=None,
                                  lengths=lengths, cache=cache,
                                  pages=pages, remat=False)
    logits = unembed(params, x, cfg)
    return logits[:, 0], new_cache
