"""Mixture-of-Experts FFN with token-choice top-k routing.

Two execution paths:

  * **Local** (no mesh — CPU tests): capacity-based scatter/gather
    dispatch on the whole batch.
  * **Expert-parallel shard_map** (under a mesh): tokens stay in their
    (pod, data, model) shards; each shard dispatches its own tokens into
    per-expert capacity buffers, an ``all_to_all`` over 'model' moves
    them to their expert's shard, experts run dense SwiGLU (weights
    FSDP-gathered over 'data' per layer), and a reverse ``all_to_all``
    returns outputs for the local weighted combine. This is the
    Switch-Transformer dispatch mapped onto jax collectives — the
    GSPMD scatter formulation replicates the dispatch buffers.

  Experts are padded up to a multiple of the model axis (qwen2-moe's 60
  -> 64) with router logits masked to -inf: routing never reaches pads.

Shared experts (qwen2-moe) run as a dense sigmoid-gated MLP on the side.
Aux load-balance loss follows Shazeer et al. (f_e * P_e).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import partitioning
from repro.core import compat
from repro.core import quant
from repro.core.types import ModelConfig
from repro.kernels import ops

MODEL_AXIS_FOR_PADDING = 16


def padded_experts(cfg: ModelConfig) -> int:
    e = cfg.moe.n_experts
    m = MODEL_AXIS_FOR_PADDING
    return -(-e // m) * m if e >= m else e


def init(key, cfg: ModelConfig, stack: Optional[int], dtype):
    mo = cfg.moe
    d, f = cfg.d_model, mo.d_ff
    e = padded_experts(cfg)
    lead = () if stack is None else (stack,)
    llead = () if stack is None else ("layers",)
    ks = jax.random.split(key, 6)

    def w(k, *shape):
        return (jax.random.normal(k, lead + shape, jnp.float32)
                / math.sqrt(shape[-2])).astype(dtype)

    params = {
        "router": w(ks[0], d, e),
        "wi": w(ks[1], e, d, f),
        "wg": w(ks[2], e, d, f),
        "wo": w(ks[3], e, f, d),
    }
    specs = {
        "router": llead + ("embed", None),
        "wi": llead + ("experts", "embed", None),
        "wg": llead + ("experts", "embed", None),
        "wo": llead + ("experts", None, "embed"),
    }
    if mo.n_shared:
        fs = mo.d_ff * mo.n_shared
        params["shared_wi"] = w(ks[4], d, fs)
        params["shared_wg"] = w(ks[5], d, fs)
        params["shared_wo"] = (jax.random.normal(
            jax.random.fold_in(key, 7), lead + (fs, d), jnp.float32)
            / math.sqrt(fs)).astype(dtype)
        params["shared_gate"] = jnp.zeros(lead + (d, 1), dtype)
        specs.update({"shared_wi": llead + ("embed", "ffn"),
                      "shared_wg": llead + ("embed", "ffn"),
                      "shared_wo": llead + ("ffn", "embed"),
                      "shared_gate": llead + ("embed", None)})
    return params, specs


def _route(xf, router_w, cfg: ModelConfig, e_pad: int):
    """-> (gate_vals (T,k), gate_idx (T,k), probs (T,E_pad))."""
    mo = cfg.moe
    logits = jnp.dot(xf.astype(jnp.float32),
                     router_w.astype(jnp.float32))          # (T, E_pad)
    if e_pad != mo.n_experts:                               # mask pads
        col = jnp.arange(e_pad)
        logits = jnp.where(col < mo.n_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, mo.top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    return gate_vals, gate_idx, probs


def _dispatch_indices(gate_idx, e_pad: int, cap: int):
    """-> (slot (T*k,) in [0, e_pad*cap] (last = dropped), token_idx)."""
    t, k = gate_idx.shape
    onehot = jax.nn.one_hot(gate_idx, e_pad, dtype=jnp.int32)
    flat = onehot.reshape(t * k, e_pad)
    pos = jnp.sum((jnp.cumsum(flat, axis=0) - flat) * flat, axis=-1)
    eid = gate_idx.reshape(t * k)
    keep = pos < cap
    slot = jnp.where(keep, eid * cap + pos, e_pad * cap)
    token_idx = jnp.repeat(jnp.arange(t), k)
    return slot, keep, token_idx


def _expert_mlp(x, wi, wg, wo):
    """x: (E, C, d); weights (E, d, f)/(E, f, d). fp32 compute."""
    xf = x.astype(jnp.float32)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xf,
                               wg.astype(jnp.float32)))
    h = jnp.einsum("ecd,edf->ecf", xf, wi.astype(jnp.float32)) * g
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(jnp.float32))


def _aux_loss(gate_idx, probs, cfg: ModelConfig):
    mo = cfg.moe
    e = probs.shape[-1]
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, e), axis=1), axis=0)
    p_e = jnp.mean(probs, axis=0)
    return mo.n_experts * jnp.sum(f_e * p_e) * mo.router_aux_coef


def _shared_expert(params, xf):
    sg = jax.nn.silu(jnp.dot(xf.astype(jnp.float32),
                             params["shared_wg"].astype(jnp.float32)))
    sh = jnp.dot(xf.astype(jnp.float32),
                 params["shared_wi"].astype(jnp.float32)) * sg
    s_out = jnp.dot(sh, params["shared_wo"].astype(jnp.float32))
    s_gate = jax.nn.sigmoid(jnp.dot(
        xf.astype(jnp.float32), params["shared_gate"].astype(jnp.float32)))
    return s_gate * s_out


def _apply_local(params, x, *, cfg: ModelConfig):
    """Single-shard dispatch (tests / no mesh)."""
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    e = padded_experts(cfg)
    k = mo.top_k
    cap = max(int(t * k / mo.n_experts * mo.capacity_factor), k)
    xf = x.reshape(t, d)
    gate_vals, gate_idx, probs = _route(xf, params["router"], cfg, e)
    slot, keep, token_idx = _dispatch_indices(gate_idx, e, cap)
    buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].set(xf[token_idx])
    expert_out = _expert_mlp(buf[:e * cap].reshape(e, cap, d),
                             params["wi"], params["wg"], params["wo"])
    flat_out = expert_out.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None],
                         flat_out[jnp.minimum(slot, e * cap - 1)], 0.0)
    out = jnp.zeros((t, d), jnp.float32).at[token_idx].add(
        gathered * gate_vals.reshape(t * k, 1))
    if mo.n_shared:
        out = out + _shared_expert(params, xf)
    return (out.reshape(b, s, d).astype(x.dtype),
            _aux_loss(gate_idx, probs, cfg))


def _apply_ep(params, x, *, cfg: ModelConfig, mesh):
    """Expert-parallel shard_map dispatch over the 'model' axis."""
    mo = cfg.moe
    b, s, d = x.shape
    e = padded_experts(cfg)
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    e_loc = e // n_model
    x_spec = partitioning.resolve(("batch", "seq", "act_embed"),
                                  mesh, shape=x.shape)
    wi_spec = P("model", "data", None)   # (E, d, f): E over EP, d FSDP
    wo_spec = P("model", None, "data")   # (E, f, d)
    rep = P()
    shared = {k: params[k] for k in
              ("shared_wi", "shared_wg", "shared_wo", "shared_gate")
              if k in params}

    def body(xl, router, wi, wg, wo, shared_w):
        bl, sl, _ = xl.shape
        t_l = bl * sl
        xf = xl.reshape(t_l, d)
        gate_vals, gate_idx, probs = _route(xf, router, cfg, e)
        cap = max(int(t_l * mo.top_k / mo.n_experts
                      * mo.capacity_factor), mo.top_k)
        slot, keep, token_idx = _dispatch_indices(gate_idx, e, cap)
        buf = jnp.zeros((e * cap + 1, d), xf.dtype
                        ).at[slot].set(xf[token_idx])
        buf = buf[:e * cap].reshape(e, cap, d)
        # dispatch all-to-all: (E, C, d) -> (E_loc, n_model*C, d)
        recv = jax.lax.all_to_all(buf, "model", split_axis=0,
                                  concat_axis=1, tiled=True)
        # FSDP: gather this layer's expert weights over 'data'
        wi_f = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
        wg_f = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
        wo_f = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        out_e = _expert_mlp(recv, wi_f, wg_f, wo_f).astype(xf.dtype)
        # return all-to-all: (E_loc, n_model*C, d) -> (E, C, d)
        back = jax.lax.all_to_all(out_e, "model", split_axis=1,
                                  concat_axis=0, tiled=True)
        flat_out = back.reshape(e * cap, d)
        gathered = jnp.where(keep[:, None],
                             flat_out[jnp.minimum(slot, e * cap - 1)], 0.0)
        out = jnp.zeros((t_l, d), jnp.float32).at[token_idx].add(
            gathered * gate_vals.reshape(-1, 1))
        if shared_w:
            out = out + _shared_expert(shared_w, xf)
        # aux from *globally* averaged routing statistics so the value is
        # identical on every shard (and equals the single-device value)
        f_e = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, e), axis=1),
                       axis=0)
        p_e = jnp.mean(probs, axis=0)
        for ax in mesh.axis_names:
            f_e = jax.lax.pmean(f_e, ax)
            p_e = jax.lax.pmean(p_e, ax)
        aux = (mo.n_experts * jnp.sum(f_e * p_e) * mo.router_aux_coef)
        return out.reshape(bl, sl, d).astype(xl.dtype), aux

    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, rep, wi_spec, wi_spec, wo_spec,
                  {k: rep for k in shared}),
        out_specs=(x_spec, rep),
        check_vma=False)
    return fn(x, params["router"], params["wi"], params["wg"],
              params["wo"], shared)


def apply(params, x, *, cfg: ModelConfig):
    """x: (B, S, d) -> (out, aux_loss)."""
    # Weight-only int8 trees: the expert einsums consume the (E, d, f)
    # leaves directly (no ops.matmul in between), so dequantize here.
    if any(quant.is_quantized(params[k]) for k in ("wi", "wg", "wo")):
        params = dict(params)
        for k in ("wi", "wg", "wo"):
            params[k] = quant.resolve_weight(params[k])
    mesh = partitioning.active_mesh()
    e = padded_experts(cfg)
    if mesh is not None and "model" in mesh.axis_names:
        n_model = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        if e % n_model == 0:
            return _apply_ep(params, x, cfg=cfg, mesh=mesh)
    return _apply_local(params, x, cfg=cfg)
