"""Vision transformers (Swin / ViT) — the paper's own target workload.

Exercises the row-wise kernels end-to-end exactly as the ASIC does:
patch-embed conv -> the same matmul primitive (Sec. IV-C), FC layers ->
row-wise matmul (Sec. IV-D), W-MSA -> Q-stationary attention within 7x7
windows (Sec. IV-E). Used by the vision example and the paper-table
benchmarks.

With pipeline fusion on (the default, see DESIGN.md §3) a block runs as
four dense-pipeline kernel launches — [ln1-prologue + qkv],
[proj + residual], [ln2-prologue + mlp1 + gelu], [mlp2 + residual] —
plus the flash window-attention kernel, which takes the
relative-position bias (and shift mask) as an additive score-bias
operand instead of materializing dense 49x49 score matrices. With
fusion off the seed's per-op composition (separate norm kernels, dense
windowed scores, XLA residual adds) is preserved as the before/after
baseline.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.swin_t import SwinConfig, ViTConfig
from repro.core import runtime
from repro.kernels import ops


def _w(key, din, dout, dtype):
    return (jax.random.normal(key, (din, dout), jnp.float32)
            / math.sqrt(din)).astype(dtype)


def _window_partition(x, w):
    b, h, wd, c = x.shape
    x = x.reshape(b, h // w, w, wd // w, w, c)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(-1, w * w, c)


def _window_reverse(xw, w, h, wd):
    b = xw.shape[0] // ((h // w) * (wd // w))
    x = xw.reshape(b, h // w, wd // w, w, w, -1)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, h, wd, -1)


def _rel_pos_index(w: int):
    coords = jnp.stack(jnp.meshgrid(jnp.arange(w), jnp.arange(w),
                                    indexing="ij"), 0).reshape(2, -1)
    rel = coords[:, :, None] - coords[:, None, :]
    rel = rel + (w - 1)
    return rel[0] * (2 * w - 1) + rel[1]          # (w*w, w*w)


def _shift_mask(h, wd, w, shift):
    """Attention mask for shifted windows (standard Swin)."""
    img = jnp.zeros((1, h, wd, 1))
    cnt = 0
    slices = (slice(0, -w), slice(-w, -shift), slice(-shift, None))
    for hs in slices:
        for ws in slices:
            img = img.at[:, hs, ws, :].set(cnt)
            cnt += 1
    mw = _window_partition(img, w).reshape(-1, w * w)
    diff = mw[:, :, None] - mw[:, None, :]
    return jnp.where(diff == 0, 0.0, -1e9)        # (nW, w*w, w*w)


def init_swin(key, cfg: SwinConfig, dtype=jnp.float32):
    ks = iter(jax.random.split(key, 256))
    d = cfg.embed_dim
    params = {
        "patch_w": _w(next(ks), cfg.patch * cfg.patch * cfg.in_chans, d,
                      dtype),
        "patch_b": jnp.zeros((d,), dtype),
        "stages": [],
        "norm_g": None, "norm_b": None,
    }
    c = d
    for si, (depth, heads) in enumerate(zip(cfg.depths, cfg.num_heads)):
        stage = {"blocks": []}
        for _bi in range(depth):
            blk = {
                "ln1_g": jnp.ones((c,), dtype), "ln1_b": jnp.zeros((c,), dtype),
                "qkv": _w(next(ks), c, 3 * c, dtype),
                "qkv_b": jnp.zeros((3 * c,), dtype),
                "proj": _w(next(ks), c, c, dtype),
                "proj_b": jnp.zeros((c,), dtype),
                "ln2_g": jnp.ones((c,), dtype), "ln2_b": jnp.zeros((c,), dtype),
                "mlp1": _w(next(ks), c, int(cfg.mlp_ratio * c), dtype),
                "mlp1_b": jnp.zeros((int(cfg.mlp_ratio * c),), dtype),
                "mlp2": _w(next(ks), int(cfg.mlp_ratio * c), c, dtype),
                "mlp2_b": jnp.zeros((c,), dtype),
                "rel_bias": (jax.random.normal(
                    next(ks), ((2 * cfg.window - 1) ** 2, heads),
                    jnp.float32) * 0.02).astype(dtype),
            }
            stage["blocks"].append(blk)
        if si < len(cfg.depths) - 1:
            stage["merge"] = _w(next(ks), 4 * c, 2 * c, dtype)
            c *= 2
        params["stages"].append(stage)
    params["norm_g"] = jnp.ones((c,), dtype)
    params["norm_b"] = jnp.zeros((c,), dtype)
    params["head"] = _w(next(ks), c, cfg.num_classes, dtype)
    params["head_b"] = jnp.zeros((cfg.num_classes,), dtype)
    return params


def _rel_bias(blk, rel_idx, heads, shift, mask):
    """Additive score bias (nb, heads, t, t): the relative-position
    table gathered per window geometry, plus the shift mask per
    window position when the block is shifted."""
    t = rel_idx.shape[0]
    rel = jnp.take(blk["rel_bias"], rel_idx.reshape(-1), axis=0)
    bias = rel.reshape(t, t, heads).transpose(2, 0, 1)[None]   # (1,h,t,t)
    if shift:
        bias = bias + mask[:, None]                 # (nW_img, h, t, t)
    return bias


def _wmsa(blk, x, heads, w, shift, rel_idx, mask):
    """Seed per-op window attention: dense 49x49 scores, separate
    norm/residual launches handled by the caller. Kept as the
    pipeline-fusion-off baseline."""
    b, h, wd, c = x.shape
    hd = c // heads
    if shift:
        x = jnp.roll(x, (-shift, -shift), axis=(1, 2))
    xw = _window_partition(x, w)                   # (B*nW, w*w, C)
    qkv = ops.matmul(xw, blk["qkv"], bias=blk["qkv_b"])
    q, k, v = jnp.split(qkv, 3, axis=-1)
    nw, t, _ = q.shape

    def heads_of(z):
        return z.reshape(nw, t, heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads_of(q), heads_of(k), heads_of(v)
    s = jnp.einsum("nhqd,nhkd->nhqk", q, k) * hd ** -0.5
    bias = jnp.take(blk["rel_bias"], rel_idx.reshape(-1), axis=0)
    s = s + bias.reshape(t, t, heads).transpose(2, 0, 1)[None]
    if shift:
        n_img = (h // w) * (wd // w)
        s = s.reshape(-1, n_img, heads, t, t) + mask[None, :, None]
        s = s.reshape(nw, heads, t, t)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("nhqk,nhkd->nhqd", p, v)
    o = o.transpose(0, 2, 1, 3).reshape(nw, t, c)
    o = ops.matmul(o, blk["proj"], bias=blk["proj_b"])
    x = _window_reverse(o, w, h, wd)
    if shift:
        x = jnp.roll(x, (shift, shift), axis=(1, 2))
    return x


def _swin_block_fused(blk, x, heads, w, shift, rel_idx, mask):
    """One Swin block as the fused pipeline: [ln1-prologue + qkv],
    flash window attention with the bias operand, [proj + residual],
    [ln2-prologue + mlp1 + gelu], [mlp2 + residual]."""
    b, h, wd, c = x.shape
    hd = c // heads
    xr = jnp.roll(x, (-shift, -shift), axis=(1, 2)) if shift else x
    xw = _window_partition(xr, w)                  # (B*nW, t, C)
    nw, t, _ = xw.shape
    # Swin stores qkv pre-fused since the seed — the LM params adopted
    # the same layout in PR 4, and both now route through ops.qkv_proj.
    q, k, v = ops.qkv_proj(xw, blk["qkv"], (c, c, c), bias=blk["qkv_b"],
                           norm=ops.NormSpec("layer", blk["ln1_g"],
                                             blk["ln1_b"]))

    def heads_of(z):
        return z.reshape(nw, t, heads, hd).transpose(0, 2, 1, 3)

    bias = _rel_bias(blk, rel_idx, heads, shift, mask)
    o = ops.attention(heads_of(q), heads_of(k), heads_of(v),
                      causal=False, bias=bias)
    o = o.transpose(0, 2, 1, 3).reshape(nw, t, c)
    # residual add in window layout == image layout (pure permutation)
    o = ops.matmul(o, blk["proj"], bias=blk["proj_b"], residual=xw)
    xr = _window_reverse(o, w, h, wd)
    x = jnp.roll(xr, (shift, shift), axis=(1, 2)) if shift else xr

    xf = x.reshape(-1, c)
    hdn = ops.matmul(xf, blk["mlp1"], bias=blk["mlp1_b"],
                     activation="gelu",
                     norm=ops.NormSpec("layer", blk["ln2_g"],
                                       blk["ln2_b"]))
    return ops.matmul(hdn, blk["mlp2"], bias=blk["mlp2_b"],
                      residual=xf).reshape(x.shape)


def swin_forward(params, images, cfg: SwinConfig):
    """images: (B, H, W, 3) -> logits (B, classes)."""
    w = cfg.window
    x = ops.patch_embed(images, params["patch_w"], params["patch_b"],
                        patch=cfg.patch)          # (B, H/4, W/4, D)
    rel_idx = _rel_pos_index(w)
    fuse = runtime.pipeline_fusion()
    for si, (_depth, heads) in enumerate(zip(cfg.depths, cfg.num_heads)):
        stage = params["stages"][si]
        b, h, wd, c = x.shape
        mask = _shift_mask(h, wd, w, w // 2) if h > w else None
        for bi, blk in enumerate(stage["blocks"]):
            shift = (w // 2) if (bi % 2 == 1 and h > w) else 0
            if fuse:
                x = _swin_block_fused(blk, x, heads, w, shift, rel_idx,
                                      mask)
                continue
            res = x
            xn = ops.layernorm(x.reshape(-1, c), blk["ln1_g"],
                               blk["ln1_b"]).reshape(x.shape)
            x = res + _wmsa(blk, xn, heads, w, shift, rel_idx, mask)
            res = x
            xn = ops.layernorm(x.reshape(-1, c), blk["ln2_g"],
                               blk["ln2_b"]).reshape(x.shape)
            hdn = ops.matmul(xn, blk["mlp1"], bias=blk["mlp1_b"],
                             activation="gelu")
            x = res + ops.matmul(hdn, blk["mlp2"], bias=blk["mlp2_b"])
        if "merge" in stage:
            b, h, wd, c = x.shape
            x = x.reshape(b, h // 2, 2, wd // 2, 2, c)
            x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, h // 2, wd // 2,
                                                      4 * c)
            x = ops.matmul(x, stage["merge"])
    b, h, wd, c = x.shape
    x = ops.layernorm(x.reshape(-1, c), params["norm_g"],
                      params["norm_b"]).reshape(b, h * wd, c)
    x = jnp.mean(x, axis=1)
    return ops.matmul(x, params["head"], bias=params["head_b"],
                      out_dtype=jnp.float32)


# ------------------------------- ViT ----------------------------------


def init_vit(key, cfg: ViTConfig, dtype=jnp.float32):
    ks = iter(jax.random.split(key, 128))
    d = cfg.embed_dim
    tokens = (cfg.img_size // cfg.patch) ** 2
    params = {
        "patch_w": _w(next(ks), cfg.patch * cfg.patch * cfg.in_chans, d,
                      dtype),
        "patch_b": jnp.zeros((d,), dtype),
        "cls": jnp.zeros((1, 1, d), dtype),
        "pos": (jax.random.normal(next(ks), (1, tokens + 1, d),
                                  jnp.float32) * 0.02).astype(dtype),
        "blocks": [],
    }
    for _ in range(cfg.depth):
        blk = {
            "ln1_g": jnp.ones((d,), dtype), "ln1_b": jnp.zeros((d,), dtype),
            "qkv": _w(next(ks), d, 3 * d, dtype),
            "proj": _w(next(ks), d, d, dtype),
            "ln2_g": jnp.ones((d,), dtype), "ln2_b": jnp.zeros((d,), dtype),
            "mlp1": _w(next(ks), d, int(cfg.mlp_ratio * d), dtype),
            "mlp2": _w(next(ks), int(cfg.mlp_ratio * d), d, dtype),
        }
        params["blocks"].append(blk)
    params["norm_g"] = jnp.ones((d,), dtype)
    params["norm_b"] = jnp.zeros((d,), dtype)
    params["head"] = _w(next(ks), d, cfg.num_classes, dtype)
    return params


def vit_forward(params, images, cfg: ViTConfig):
    x = ops.patch_embed(images, params["patch_w"], params["patch_b"],
                        patch=cfg.patch)
    b = x.shape[0]
    d = cfg.embed_dim
    x = x.reshape(b, -1, d)
    x = jnp.concatenate([jnp.broadcast_to(params["cls"], (b, 1, d)), x], 1)
    x = x + params["pos"].astype(x.dtype)
    heads = cfg.num_heads
    hd = d // heads
    fuse = runtime.pipeline_fusion()
    for blk in params["blocks"]:
        def hsplit(z):
            return z.reshape(b, -1, heads, hd).transpose(0, 2, 1, 3)

        if fuse:
            qkv = ops.matmul(x, blk["qkv"],
                             norm=ops.NormSpec("layer", blk["ln1_g"],
                                               blk["ln1_b"]))
            q, k, v = jnp.split(qkv, 3, axis=-1)
            o = ops.attention(hsplit(q), hsplit(k), hsplit(v),
                              causal=False)
            o = o.transpose(0, 2, 1, 3).reshape(b, -1, d)
            x = ops.matmul(o, blk["proj"], residual=x)
            h = ops.matmul(x, blk["mlp1"], activation="gelu",
                           norm=ops.NormSpec("layer", blk["ln2_g"],
                                             blk["ln2_b"]))
            x = ops.matmul(h, blk["mlp2"], residual=x)
            continue
        xn = ops.layernorm(x, blk["ln1_g"], blk["ln1_b"])
        qkv = ops.matmul(xn, blk["qkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        o = ops.attention(hsplit(q), hsplit(k), hsplit(v), causal=False)
        o = o.transpose(0, 2, 1, 3).reshape(b, -1, d)
        x = x + ops.matmul(o, blk["proj"])
        xn = ops.layernorm(x, blk["ln2_g"], blk["ln2_b"])
        h = ops.matmul(xn, blk["mlp1"], activation="gelu")
        x = x + ops.matmul(h, blk["mlp2"])
    x = ops.layernorm(x, params["norm_g"], params["norm_b"])
    return ops.matmul(x[:, 0], params["head"], out_dtype=jnp.float32)
