"""Rotary embeddings: standard (neox-style) and M-RoPE (qwen2-vl).

M-RoPE splits the head-dim rotation frequencies into (t, h, w) sections;
text tokens carry identical (t,h,w) positions (reducing to 1-D RoPE),
vision patch embeddings carry their (frame, row, col) indices.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def mrope_sections(hd: int) -> Tuple[int, int, int]:
    """Default (t,h,w) split of the half-dim (qwen2-vl uses 16/24/24 @128)."""
    half = hd // 2
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray,
                theta: float = 10_000.0,
                sections: Tuple[int, int, int] = None) -> jnp.ndarray:
    """x: (B, S, H, hd); positions3: (3, B, S) int32 for (t, h, w)."""
    hd = x.shape[-1]
    half = hd // 2
    if sections is None:
        sections = mrope_sections(hd)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # section id per frequency index
    sec = jnp.concatenate([jnp.full((n,), i, jnp.int32)
                           for i, n in enumerate(sections)])
    # pos per (B,S,half): pick t/h/w position stream per frequency
    pos = jnp.take_along_axis(
        positions3.transpose(1, 2, 0).astype(jnp.float32),      # (B,S,3)
        jnp.broadcast_to(sec[None, None, :],
                         positions3.shape[1:] + (half,)), axis=-1)
    ang = pos * freqs                                           # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def text_positions3(positions: jnp.ndarray) -> jnp.ndarray:
    """Text-only M-RoPE positions: t = h = w = position."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)


def sinusoidal_embedding(seq_len: int, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal absolute positions (S, d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(seq_len)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
