"""Transformer/SSM block assembly from BlockDefs.

A block = pre-norm mixer (+ residual) then pre-norm FFN (+ residual),
with the mixer/FFN kinds taken from the config's stage compilation
(attn / mamba2 / rwkv6 x mlp / moe / rwkv6_cmix / none). All dense ops
route through the row-wise primitive.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import runtime
from repro.core.partitioning import logical_constraint
from repro.core.types import BlockDef, ModelConfig
from repro.kernels import ops
from repro.models import attention, mamba2, mlp, moe, rwkv6


def _norm_init(cfg: ModelConfig, stack, dtype, name="g"):
    d = cfg.d_model
    lead = () if stack is None else (stack,)
    llead = () if stack is None else ("layers",)
    p = {"g": jnp.ones(lead + (d,), dtype)}
    s = {"g": llead + (None,)}
    if cfg.norm == "layer":
        p["b"] = jnp.zeros(lead + (d,), dtype)
        s["b"] = llead + (None,)
    return p, s


def _norm_apply(p, x, cfg: ModelConfig):
    return ops.layernorm(x, p["g"], p.get("b"), kind=cfg.norm)


def _norm_spec(p, cfg: ModelConfig) -> ops.NormSpec:
    return ops.NormSpec(cfg.norm, p["g"], p.get("b"))


def init_block(key, blk: BlockDef, cfg: ModelConfig, stack, dtype):
    ks = jax.random.split(key, 6)
    params, specs = {}, {}
    params["norm1"], specs["norm1"] = _norm_init(cfg, stack, dtype)
    if blk.mixer == "attn":
        params["attn"], specs["attn"] = attention.init(ks[0], cfg, stack,
                                                       dtype)
    elif blk.mixer == "mamba2":
        params["mamba"], specs["mamba"] = mamba2.init(ks[0], cfg, stack,
                                                      dtype)
    elif blk.mixer == "rwkv6":
        params["tmix"], specs["tmix"] = rwkv6.init(ks[0], cfg, stack, dtype)
    if blk.cross_attn:
        params["norm_x"], specs["norm_x"] = _norm_init(cfg, stack, dtype)
        params["cross"], specs["cross"] = attention.init(ks[1], cfg, stack,
                                                         dtype, cross=True)
    if blk.ffn != "none":
        params["norm2"], specs["norm2"] = _norm_init(cfg, stack, dtype)
    if blk.ffn == "mlp":
        params["ffn"], specs["ffn"] = mlp.init(ks[2], cfg, stack, dtype)
    elif blk.ffn == "moe":
        params["ffn"], specs["ffn"] = moe.init(ks[2], cfg, stack, dtype)
    elif blk.ffn == "rwkv6_cmix":
        params["ffn"], specs["ffn"] = mlp.init_cmix(ks[2], cfg, stack,
                                                    dtype)
    return params, specs


class BlockIO(NamedTuple):
    """Everything a block may consume/produce besides the hidden state."""
    aux: jnp.ndarray                      # scalar aux loss accumulator
    new_cache: Any = None                 # decode: updated cache slice
    prefill_state: Any = None             # prefill: (k,v) or mixer state


def apply_block(blk: BlockDef, params, x, *, cfg: ModelConfig, mode: str,
                positions=None, lengths=None, cache=None, enc_out=None,
                pages=None, chunk_len=None,
                window_override: Optional[int] = None) -> tuple:
    """mode: 'train' | 'prefill' | 'decode' | 'chunk' | 'verify'.
    Returns (x, BlockIO).

    pages: (B, max_pages) int32 block table for paged decode — required
    when the decode cache's KV leaf is a :class:`PagedKVCache` pool.
    'chunk' is the serving engine's chunked-prefill mode: x is a row
    panel of prompt tokens at position offset ``lengths`` (the tokens
    already in the paged cache, exactly the decode-mode semantics) of
    which the first ``chunk_len`` are real; attention layers attend
    prefix pages + the in-flight chunk and append their KV. Only
    causal-attention archs may chunk (``paging.supports_bucketing`` —
    recurrent mixers would fold the split into their state).
    'verify' is the speculative-decode scoring mode: same panel
    semantics as 'chunk' (now batched, per-row offsets/lengths) but the
    pool is read-only — each layer returns its panel (k, v) as
    ``prefill_state`` and the engine writes only accepted rows after
    acceptance (:func:`lm.insert_verify`).
    """
    if mode in ("chunk", "verify"):
        assert blk.mixer == "attn" and not blk.cross_attn, (
            "chunked prefill requires every position's state to be "
            f"causal-attention KV; {blk.mixer}/cross_attn blocks must "
            "prefill in one shot (paging.supports_bucketing)")
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    prefill_state = {}
    window = blk.window if window_override is None else window_override
    # Fused pipeline (DESIGN.md §3): the attn/mlp sublayers take the RAW
    # hidden state plus a NormSpec — the pre-norm runs as the qkv /
    # gate-up kernel prologue and the residual add rides the output
    # projection's epilogue, so neither intermediate exists in HBM.
    fuse = runtime.pipeline_fusion()

    if blk.mixer == "attn":
        nspec = _norm_spec(params["norm1"], cfg) if fuse else None
        h = x if fuse else _norm_apply(params["norm1"], x, cfg)
        res = x if fuse else None
        if mode == "decode":
            if isinstance(cache["kv"], attention.PagedKVCache):
                out, kv_new = attention.paged_decode_apply(
                    params["attn"], h, cache["kv"], cfg=cfg,
                    lengths=lengths, pages=pages, window=window,
                    norm=nspec, residual=res)
            else:
                out, kv_new = attention.decode_apply(
                    params["attn"], h, cache["kv"], cfg=cfg,
                    lengths=lengths, window=window, norm=nspec,
                    residual=res)
            new_cache["kv"] = kv_new
        elif mode == "chunk":
            out, kv_new = attention.paged_chunk_apply(
                params["attn"], h, cache["kv"], cfg=cfg, offset=lengths,
                chunk_len=chunk_len, pages=pages, window=window,
                norm=nspec, residual=res)
            new_cache["kv"] = kv_new
        elif mode == "verify":
            out, (k, v) = attention.paged_verify_apply(
                params["attn"], h, cache["kv"], cfg=cfg, offset=lengths,
                chunk_len=chunk_len, pages=pages, window=window,
                norm=nspec, residual=res)
            prefill_state["kv"] = (k, v)
        else:
            out, (k, v) = attention.apply(params["attn"], h, cfg=cfg,
                                          positions=positions,
                                          window=window, causal=True,
                                          norm=nspec, residual=res)
            if mode == "prefill":
                prefill_state["kv"] = (k, v)
        x = out if fuse else x + out
    elif blk.mixer == "mamba2":
        h = _norm_apply(params["norm1"], x, cfg)
        state = cache["mamba"] if mode == "decode" else None
        out, s_new = mamba2.apply(params["mamba"], h, cfg=cfg, state=state)
        if mode == "decode":
            new_cache["mamba"] = s_new
        elif mode == "prefill":
            prefill_state["mamba"] = s_new
        x = x + out
    elif blk.mixer == "rwkv6":
        h = _norm_apply(params["norm1"], x, cfg)
        state = cache["rwkv_t"] if mode == "decode" else None
        out, (x_last, wkv) = rwkv6.apply(params["tmix"], h, cfg=cfg,
                                         state=state)
        if mode in ("decode", "prefill"):
            st = {"x_prev_t": x_last, "wkv": wkv}
            if mode == "decode":
                new_cache["rwkv_t"] = st
            else:
                prefill_state["rwkv_t"] = st
        x = x + out

    if blk.cross_attn:
        h = _norm_apply(params["norm_x"], x, cfg)
        if mode == "decode":
            # cross K/V are static after prefill; cached as head-layout
            xk, xv = cache["cross_kv"]
            b = h.shape[0]
            hq, hd = cfg.n_heads, cfg.head_dim
            q = ops.matmul(h, params["cross"]["wq"]).reshape(b, 1, hq, hd)
            out = attention.chunked_attention(
                q.transpose(0, 2, 1, 3), xk.transpose(0, 2, 1, 3),
                xv.transpose(0, 2, 1, 3), causal=False, window=0)
            out = out.transpose(0, 2, 1, 3).reshape(b, 1, hq * hd)
            out = ops.matmul(out, params["cross"]["wo"])
            new_cache["cross_kv"] = cache["cross_kv"]
        else:
            out, (ck, cv) = attention.apply(
                params["cross"], h, cfg=cfg, positions=positions,
                causal=False, kv=(enc_out, enc_out))
            if mode == "prefill":
                prefill_state["cross_kv"] = (ck, cv)
        x = x + out

    if blk.ffn != "none":
        if blk.ffn == "mlp":
            if fuse:
                x = mlp.apply(params["ffn"], x, cfg=cfg,
                              norm=_norm_spec(params["norm2"], cfg),
                              residual=x)
            else:
                h = _norm_apply(params["norm2"], x, cfg)
                x = x + mlp.apply(params["ffn"], h, cfg=cfg)
        elif blk.ffn == "moe":
            h = _norm_apply(params["norm2"], x, cfg)
            out, aux_l = moe.apply(params["ffn"], h, cfg=cfg)
            x = x + out
            aux = aux + aux_l
        elif blk.ffn == "rwkv6_cmix":
            h = _norm_apply(params["norm2"], x, cfg)
            state = cache["rwkv_c"] if mode == "decode" else None
            x_last_c = (state["x_prev_c"] if mode == "decode"
                        else jnp.zeros_like(h[:, 0]))
            hp = rwkv6._token_shift(h, x_last_c)
            out = mlp.apply_cmix(params["ffn"], h, hp)
            if mode == "decode":
                new_cache["rwkv_c"] = {"x_prev_c": h[:, -1]}
            elif mode == "prefill":
                prefill_state["rwkv_c"] = {"x_prev_c": h[:, -1]}
            x = x + out
    # keep the scan carry consistently sharded so GSPMD emits the SP
    # reduce-scatter/all-gather pair instead of full all-reduces
    x = logical_constraint(x, "batch", "seq", "act_embed")
    return x, BlockIO(aux=aux, new_cache=new_cache or None,
                      prefill_state=prefill_state or None)
