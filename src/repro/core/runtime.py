"""Global runtime context: which kernel implementation the models use.

  * 'ref'       — pure-jnp oracles (XLA fuses them; default on CPU and
                  for the dry-run, so cost_analysis reflects real math)
  * 'pallas'    — compiled Pallas kernels (real TPU)
  * 'interpret' — Pallas kernels in interpret mode (CPU correctness runs)

Selected process-wide (launcher flag) or via context manager in tests.
"""
from __future__ import annotations

import contextlib

import jax

_IMPL = "auto"


def resolve_impl() -> str:
    if _IMPL != "auto":
        return _IMPL
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "ref"


def set_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("auto", "ref", "pallas", "interpret"), impl
    _IMPL = impl


@contextlib.contextmanager
def use_impl(impl: str):
    global _IMPL
    prev = _IMPL
    set_impl(impl)
    try:
        yield
    finally:
        _IMPL = prev
