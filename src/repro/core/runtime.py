"""Global runtime context: which kernel implementation the models use.

  * 'ref'       — pure-jnp oracles (XLA fuses them; default on CPU and
                  for the dry-run, so cost_analysis reflects real math)
  * 'pallas'    — compiled Pallas kernels (real TPU)
  * 'interpret' — Pallas kernels in interpret mode (CPU correctness runs)

Selected process-wide (launcher flag) or via context manager in tests.

Also owns the **pipeline-fusion** switch (PR 2): when on (default), the
models fuse the pre-norm prologue, multi-head projections and
residual/gating epilogues into single row-wise kernel launches; when
off they compose the per-op kernels the way the seed did. The off path
exists so benchmarks can report before/after launch counts and HBM
traffic for the same weights.
"""
from __future__ import annotations

import contextlib

import jax

_IMPL = "auto"
_FUSE_PIPELINE = True


def resolve_impl() -> str:
    if _IMPL != "auto":
        return _IMPL
    platform = jax.default_backend()
    return "pallas" if platform == "tpu" else "ref"


def set_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("auto", "ref", "pallas", "interpret"), impl
    _IMPL = impl


@contextlib.contextmanager
def use_impl(impl: str):
    global _IMPL
    prev = _IMPL
    set_impl(impl)
    try:
        yield
    finally:
        _IMPL = prev


def pipeline_fusion() -> bool:
    return _FUSE_PIPELINE


def set_pipeline_fusion(on: bool) -> None:
    global _FUSE_PIPELINE
    _FUSE_PIPELINE = bool(on)


@contextlib.contextmanager
def use_pipeline_fusion(on: bool):
    global _FUSE_PIPELINE
    prev = _FUSE_PIPELINE
    _FUSE_PIPELINE = bool(on)
    try:
        yield
    finally:
        _FUSE_PIPELINE = prev
