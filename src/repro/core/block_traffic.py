"""Model-level HBM-traffic accounting for one transformer block forward.

``plan_matmul`` prices a single kernel launch; this module walks a whole
Swin block — pre-norms, q/k/v + output projections, window attention,
MLP, residual adds — and sums the modeled traffic for the two execution
regimes the runtime supports:

  * ``fused=False`` — the seed's per-op pipeline: every intermediate
    (normed activations, per-projection outputs, dense window scores,
    residual sums) round-trips HBM between kernels; residual adds and
    the gating multiply are standalone XLA elementwise passes (read a,
    read b, write out).
  * ``fused=True``  — the PR 2 pipeline (DESIGN.md §3): pre-norm as the
    matmul prologue, wq|wk|wv wide-N, residual adds in epilogues, and
    flash window attention with a streamed score-bias operand instead
    of dense materialized scores.

Both regimes price each matmul with today's fused in-kernel adder tree
(PR 1) and the real output dtype, so the delta isolates the *inter-op*
traffic this PR removes. Used by ``benchmarks/block_bench.py`` (the
BENCH_PR2.json artifact) and the acceptance test.
"""
from __future__ import annotations

from repro.core.rowwise import plan_matmul

FP32 = 4


def _mm(m: int, k: int, n: int, db: int, **kw) -> int:
    return plan_matmul(m, k, n, dtype_bytes=db, out_bytes=db,
                       **kw).bytes_moved


def _norm_io(m: int, d: int, db: int) -> int:
    """Standalone norm kernel: read + write the row panel, gamma/beta."""
    return 2 * m * d * db + 2 * d * FP32


def _ew_add_io(m: int, d: int, db: int) -> int:
    """XLA residual add: read both operands, write the sum."""
    return 3 * m * d * db


def swin_block_traffic(*, grid_h: int, grid_w: int, c: int, heads: int,
                       window: int = 7, mlp_ratio: float = 4.0,
                       dtype_bytes: int = 2, batch: int = 1,
                       shifted: bool = False, fused: bool = True) -> dict:
    """Modeled HBM bytes for one Swin block forward at feature-map size
    (grid_h, grid_w) with C channels. Returns {"ops": [(name, bytes)],
    "total": int}."""
    db = dtype_bytes
    m = batch * grid_h * grid_w                 # activation rows
    t = window * window                         # tokens per window
    n_win = batch * (grid_h // window) * (grid_w // window)
    f = int(mlp_ratio * c)
    score = n_win * heads * t * t * FP32        # one dense score pass
    qkv_io = 3 * m * c * db                     # q, k, v head-layout reads
    ops = []

    if fused:
        ops.append(("ln1+qkv(wide-N)",
                    _mm(m, c, 3 * c, db, prologue=True, wide_n=True)))
        # Flash window attention: q/k/v stream once, the score bias
        # streams as an operand, the S x S matrix never exists in HBM.
        if shifted:
            # per-window bias (rel + shift mask): constructed once per
            # forward (write + mask read), re-fetched per (window, head)
            nw_img = n_win // batch
            bias = (nw_img * heads * t * t * FP32          # construct
                    + nw_img * t * t * FP32                # mask read
                    + score)                               # kernel fetch
        else:
            # broadcast bias: head-major grid keeps it VMEM-resident,
            # fetched once per head
            bias = heads * t * t * FP32
        ops.append(("flash-attn+bias", qkv_io + bias + m * c * db))
        ops.append(("proj+residual", _mm(m, c, c, db, residual=True)))
        ops.append(("ln2+mlp1+gelu",
                    _mm(m, c, f, db, prologue=True, wide_n=True)))
        ops.append(("mlp2+residual", _mm(m, f, c, db, residual=True)))
    else:
        ops.append(("ln1", _norm_io(m, c, db)))
        ops.append(("qkv", _mm(m, c, 3 * c, db)))
        # Dense windowed attention: write scores, read-modify-write for
        # bias+mask+softmax (one fused XLA pass), read probs for p@v.
        ops.append(("dense-attn", qkv_io + 4 * score + m * c * db))
        ops.append(("proj", _mm(m, c, c, db)))
        ops.append(("residual1", _ew_add_io(m, c, db)))
        ops.append(("ln2", _norm_io(m, c, db)))
        ops.append(("mlp1+gelu", _mm(m, c, f, db)))
        ops.append(("mlp2", _mm(m, f, c, db)))
        ops.append(("residual2", _ew_add_io(m, c, db)))

    return {"ops": ops, "total": sum(b for _, b in ops)}


def swin_t_stage_cases(batch: int = 1) -> dict:
    """The Swin-T (224x224) per-stage block geometries."""
    return {
        "stage1": dict(grid_h=56, grid_w=56, c=96, heads=3, batch=batch),
        "stage2": dict(grid_h=28, grid_w=28, c=192, heads=6, batch=batch),
        "stage3": dict(grid_h=14, grid_w=14, c=384, heads=12, batch=batch),
    }
