"""Model-level HBM-traffic accounting for one transformer block forward.

``plan_matmul`` prices a single kernel launch; this module walks a whole
Swin block — pre-norms, q/k/v + output projections, window attention,
MLP, residual adds — and sums the modeled traffic for the two execution
regimes the runtime supports:

  * ``fused=False`` — the seed's per-op pipeline: every intermediate
    (normed activations, per-projection outputs, dense window scores,
    residual sums) round-trips HBM between kernels; residual adds and
    the gating multiply are standalone XLA elementwise passes (read a,
    read b, write out).
  * ``fused=True``  — the PR 2 pipeline (DESIGN.md §3): pre-norm as the
    matmul prologue, wq|wk|wv wide-N, residual adds in epilogues, and
    flash window attention with a streamed score-bias operand instead
    of dense materialized scores.

Both regimes price each matmul with today's fused in-kernel adder tree
(PR 1) and the real output dtype, so the delta isolates the *inter-op*
traffic this PR removes. Used by ``benchmarks/block_bench.py`` (the
BENCH_PR2.json artifact) and the acceptance test.

The serving-side section models decode-step KV traffic the same way
for the paged engine (PR 3): dense lockstep caches stream ``n_slots x
max_len`` rows per layer per step, block-table decode streams only
each live sequence's pages. Used by ``benchmarks/serve_bench.py``
(BENCH_PR3.json) and its acceptance test.

The chunked-prefill section (PR 5) prices the serving engine's chunked
admission: the monolithic-bucket decode stall it removes against the
prefix-page re-reads resumability costs. Used by
``benchmarks/serve_bench.py`` (BENCH_PR5.json) and its acceptance test.

The decode weight-traffic section prices the PR 4 param-layout
migration: with wqkv / wgi stored pre-fused the kernels stream the
panels straight from the param tree; the PR 2 per-call regime instead
concatenated the sibling weights every call, paying a panel-sized
write + read on every decode step. Used by
``benchmarks/decode_bench.py`` (BENCH_PR4.json) and its acceptance
test.
"""
from __future__ import annotations

from repro.core.rowwise import plan_matmul
from repro.core.types import GATED_ACTS

FP32 = 4


def _mm(m: int, k: int, n: int, db: int, **kw) -> int:
    return plan_matmul(m, k, n, dtype_bytes=db, out_bytes=db,
                       **kw).bytes_moved


def _norm_io(m: int, d: int, db: int) -> int:
    """Standalone norm kernel: read + write the row panel, gamma/beta."""
    return 2 * m * d * db + 2 * d * FP32


def _ew_add_io(m: int, d: int, db: int) -> int:
    """XLA residual add: read both operands, write the sum."""
    return 3 * m * d * db


def swin_block_traffic(*, grid_h: int, grid_w: int, c: int, heads: int,
                       window: int = 7, mlp_ratio: float = 4.0,
                       dtype_bytes: int = 2, batch: int = 1,
                       shifted: bool = False, fused: bool = True) -> dict:
    """Modeled HBM bytes for one Swin block forward at feature-map size
    (grid_h, grid_w) with C channels. Returns {"ops": [(name, bytes)],
    "total": int}."""
    db = dtype_bytes
    m = batch * grid_h * grid_w                 # activation rows
    t = window * window                         # tokens per window
    n_win = batch * (grid_h // window) * (grid_w // window)
    f = int(mlp_ratio * c)
    score = n_win * heads * t * t * FP32        # one dense score pass
    qkv_io = 3 * m * c * db                     # q, k, v head-layout reads
    ops = []

    if fused:
        ops.append(("ln1+qkv(wide-N)",
                    _mm(m, c, 3 * c, db, prologue=True, wide_n=True)))
        # Flash window attention: q/k/v stream once, the score bias
        # streams as an operand, the S x S matrix never exists in HBM.
        if shifted:
            # per-window bias (rel + shift mask): constructed once per
            # forward (write + mask read), re-fetched per (window, head)
            nw_img = n_win // batch
            bias = (nw_img * heads * t * t * FP32          # construct
                    + nw_img * t * t * FP32                # mask read
                    + score)                               # kernel fetch
        else:
            # broadcast bias: head-major grid keeps it VMEM-resident,
            # fetched once per head
            bias = heads * t * t * FP32
        ops.append(("flash-attn+bias", qkv_io + bias + m * c * db))
        ops.append(("proj+residual", _mm(m, c, c, db, residual=True)))
        ops.append(("ln2+mlp1+gelu",
                    _mm(m, c, f, db, prologue=True, wide_n=True)))
        ops.append(("mlp2+residual", _mm(m, f, c, db, residual=True)))
    else:
        ops.append(("ln1", _norm_io(m, c, db)))
        ops.append(("qkv", _mm(m, c, 3 * c, db)))
        # Dense windowed attention: write scores, read-modify-write for
        # bias+mask+softmax (one fused XLA pass), read probs for p@v.
        ops.append(("dense-attn", qkv_io + 4 * score + m * c * db))
        ops.append(("proj", _mm(m, c, c, db)))
        ops.append(("residual1", _ew_add_io(m, c, db)))
        ops.append(("ln2", _norm_io(m, c, db)))
        ops.append(("mlp1+gelu", _mm(m, c, f, db)))
        ops.append(("mlp2", _mm(m, f, c, db)))
        ops.append(("residual2", _ew_add_io(m, c, db)))

    return {"ops": ops, "total": sum(b for _, b in ops)}


# ----------------------------------------------------------------------
# Decode-step projection-weight traffic: pre-fused param layout (PR 4)
# vs the per-call sibling-panel concat regime (PR 2)
# ----------------------------------------------------------------------


def decode_weight_traffic(*, n_slots: int, d_model: int, n_heads: int,
                          n_kv_heads: int, head_dim: int, d_ff: int,
                          gated: bool = True, dtype_bytes: int = 2,
                          prefused: bool = True) -> dict:
    """Modeled HBM bytes for ONE attn+MLP block decode step at
    M = n_slots rows — the regime where weight streaming dwarfs the
    activation traffic (ViTA's edge-transformer observation).

    ``prefused=True`` is the PR 4 param layout: wqkv and wgi live as
    single leaves, so the kernels stream the stored panels directly and
    the only weight traffic is the panel fetch itself.
    ``prefused=False`` prices the PR 2 per-call regime: the sibling
    projections are separate leaves that ``ops.qkv_proj`` /
    ``ops.gate_up_proj`` fuse per call — XLA reads every part and
    writes the concatenated panel before the kernel fetches it back,
    an extra 2x the panel's (true, unpadded) bytes of pure
    weight-stream traffic on EVERY decode step.

    Returns {"ops": [(name, total_bytes, weight_bytes)],
             "total": int, "weight_bytes": int}.
    """
    db = dtype_bytes
    m = n_slots
    qo, kvo = n_heads * head_dim, n_kv_heads * head_dim
    rows = []
    weight_total = 0

    def mm(name, k, n, *, n_weights=1, cat=False, **kw):
        nonlocal weight_total
        plan = plan_matmul(m, k, n, dtype_bytes=db, out_bytes=db,
                           n_weights=n_weights, **kw)
        w_factor = 1 if plan.k_splits == 1 else plan.m_pad // plan.bm
        w_bytes = plan.k_pad * plan.n_pad * db * n_weights * w_factor
        total = plan.bytes_moved
        if cat and not prefused:
            extra = 2 * k * n * n_weights * db     # parts read + cat write
            total += extra
            w_bytes += extra
        weight_total += w_bytes
        rows.append((name, total, w_bytes))

    mm("qkv", d_model, qo + 2 * kvo, cat=True, prologue=True, wide_n=True)
    mm("wo+residual", qo, d_model, residual=True)
    if gated:
        mm("gate|up", d_model, d_ff, n_weights=2, cat=True,
           prologue=True, wide_n=True)
    else:
        mm("mlp1", d_model, d_ff, prologue=True, wide_n=True)
    mm("mlp2+residual", d_ff, d_model, residual=True)
    return {"ops": rows, "total": sum(t for _, t, _ in rows),
            "weight_bytes": weight_total}


def decode_weight_traffic_cfg(cfg, *, n_slots: int, dtype_bytes: int = 2,
                              prefused: bool = True) -> dict:
    """:func:`decode_weight_traffic` with dims pulled from a ModelConfig."""
    return decode_weight_traffic(
        n_slots=n_slots, d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim, d_ff=cfg.d_ff,
        gated=cfg.act in GATED_ACTS, dtype_bytes=dtype_bytes,
        prefused=prefused)


# ----------------------------------------------------------------------
# Serving-side KV traffic: paged block-table decode vs dense lockstep
# ----------------------------------------------------------------------


def kv_layer_counts(cfg) -> tuple:
    """(n_global, n_local, window) attention-layer counts of a config.
    The model prices one window size; configs mixing several would need
    per-window counts, so that case is rejected rather than mispriced."""
    n_global = n_local = window = 0
    for stage in cfg.stages():
        for blk in stage.body:
            if blk.mixer != "attn":
                continue
            if blk.window:
                assert window in (0, blk.window), (
                    f"mixed sliding windows ({window}, {blk.window}) "
                    "need per-window traffic accounting")
                n_local += stage.repeat
                window = blk.window
            else:
                n_global += stage.repeat
    return n_global, n_local, window


def dense_kv_step_bytes(*, n_slots: int, max_len: int, n_global: int,
                        n_local: int = 0, window: int = 0,
                        n_kv_heads: int, head_dim: int,
                        dtype_bytes: int = 2) -> int:
    """One lockstep decode step against the seed's dense per-slot
    caches: every attention layer streams its whole ``(n_slots, alloc)``
    K and V buffers regardless of how many tokens are live (windowed
    layers allocate ``min(window, max_len)``)."""
    row = 2 * n_kv_heads * head_dim * dtype_bytes          # K + V
    total = n_global * n_slots * max_len * row
    if n_local:
        total += n_local * n_slots * min(window, max_len) * row
    return total


def paged_kv_step_bytes(lengths, *, page_size: int, n_global: int,
                        n_local: int = 0, window: int = 0,
                        n_kv_heads: int, head_dim: int,
                        dtype_bytes: int = 2) -> int:
    """One decode step with block-table gathers: each live sequence
    fetches only its own live pages (whole pages — a partial tail page
    streams in full), windowed layers at most the ring's
    ``ceil(window / page_size)`` pages. Idle slots fetch nothing."""
    row = 2 * n_kv_heads * head_dim * dtype_bytes
    total = 0
    for ln in lengths:
        live = -(-(ln) // page_size) * page_size           # page-rounded
        total += n_global * live * row
        if n_local:
            ring = min(live, -(-min(window, ln) // page_size) * page_size)
            total += n_local * ring * row
    return total


def chunked_prefill_traffic(plen: int, *, chunk_size: int, page_size: int,
                            n_global: int, n_local: int = 0,
                            window: int = 0, n_kv_heads: int,
                            head_dim: int, dtype_bytes: int = 2) -> dict:
    """Model the chunked-prefill trade for one admitted prompt: the
    decode stall it removes vs the prefix re-read bytes it adds.

    * Stall: with monolithic bucketed prefill every co-resident decode
      slot waits for ONE program that processes the whole prompt —
      ``plen`` row-panel tokens between decode steps. Chunked prefill
      bounds that to ``chunk_size`` tokens per engine step (the paper's
      fixed-granularity row-panel execution, restored at admission).
    * Extra bytes: each chunk re-gathers the slot's already-written
      prefix pages (whole pages — a partial tail page streams in full;
      windowed layers at most the ring), KV the one-shot program kept
      on chip. This is the price of resumability, reported so the bench
      artifact shows both sides of the trade. The chunk's own KV write
      is identical in both regimes and cancels.

    Returns ``{"n_chunks", "stall_rows_one_shot", "stall_rows_chunked",
    "prefix_reread_bytes"}``.
    """
    row = 2 * n_kv_heads * head_dim * dtype_bytes          # K + V
    reread = 0
    offs = list(range(0, plen, chunk_size))
    for off in offs[1:]:                                   # chunk 0: none
        live = -(-off // page_size) * page_size            # page-rounded
        reread += n_global * live * row
        if n_local:
            ring = min(live, -(-min(window, off) // page_size) * page_size)
            reread += n_local * ring * row
    last = plen - offs[-1]
    return {"n_chunks": len(offs),
            "stall_rows_one_shot": plen,
            "stall_rows_chunked": max(chunk_size if len(offs) > 1 else 0,
                                      last),
            "prefix_reread_bytes": reread}


def chunked_prefill_traffic_cfg(cfg, plen: int, *, chunk_size: int,
                                page_size: int,
                                dtype_bytes: int = 2) -> dict:
    """:func:`chunked_prefill_traffic` with layer counts from a config."""
    n_global, n_local, window = kv_layer_counts(cfg)
    return chunked_prefill_traffic(
        plen, chunk_size=chunk_size, page_size=page_size,
        n_global=n_global, n_local=n_local, window=window,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        dtype_bytes=dtype_bytes)


def serve_kv_traffic(trace, cfg, *, n_slots: int, max_len: int,
                     page_size: int, dtype_bytes: int = 2) -> dict:
    """Sum modeled KV HBM bytes over a decode trace (a list of per-step
    live-slot length lists, as recorded by ``Engine.kv_trace``) for both
    serving regimes. The ratio is the acceptance metric: with mean live
    length << max_len, paged decode moves a small multiple of the live
    tokens while dense lockstep always moves n_slots * max_len rows."""
    n_global, n_local, window = kv_layer_counts(cfg)
    kw = dict(n_global=n_global, n_local=n_local, window=window,
              n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
              dtype_bytes=dtype_bytes)
    dense = sum(dense_kv_step_bytes(n_slots=n_slots, max_len=max_len,
                                    **kw) for _ in trace)
    paged = sum(paged_kv_step_bytes(lens, page_size=page_size, **kw)
                for lens in trace)
    # attention-free archs (rwkv) move no KV either way: parity, not 0x
    ratio = dense / paged if paged else 1.0
    return {"dense_bytes": dense, "paged_bytes": paged,
            "ratio": ratio, "steps": len(trace)}


# ----------------------------------------------------------------------
# Speculative decode: HBM bytes per ACCEPTED token
# ----------------------------------------------------------------------


def spec_step_traffic(cfg, *, lengths, accepted_total: int,
                      page_size: int, n_slots: int = None,
                      dtype_bytes: int = 2) -> dict:
    """Bytes-per-accepted-token model for ONE speculative verify step
    (PR 10) against the plain decode steps it replaces.

    Decode at serving batch sizes is weight-streaming-bound (PR 4): one
    fused-panel fetch per block per step, whatever M is. The verify
    step scores a ``1 + k`` row panel per slot through the same
    row-wise primitive — M grows, the weight fetch does not (the source
    paper's resource-reuse argument) — and its multi-query prefix
    gather reads each live page once per STEP instead of once per
    emitted token. Emitting the same ``n_live + accepted_total`` tokens
    by plain decode streams the weights and re-gathers the prefix that
    many times over.

    ``lengths``: live-slot token lengths at the step (the Engine
    ``kv_trace`` row). Returns ``{"step_bytes", "weight_bytes",
    "kv_bytes", "emitted", "bytes_per_accepted",
    "decode_bytes_per_token", "amortization"}``; with no accepted
    drafts the model degenerates to decode's own bytes/token
    (amortization 1.0).
    """
    n_live = len(lengths)
    if n_slots is None:
        n_slots = max(n_live, 1)
    w = decode_weight_traffic_cfg(cfg, n_slots=n_slots,
                                  dtype_bytes=dtype_bytes)
    n_blocks = sum(st.repeat * len(st.body) for st in cfg.stages())
    n_global, n_local, window = kv_layer_counts(cfg)
    kv = paged_kv_step_bytes(lengths, page_size=page_size,
                             n_global=n_global, n_local=n_local,
                             window=window, n_kv_heads=cfg.n_kv_heads,
                             head_dim=cfg.head_dim,
                             dtype_bytes=dtype_bytes)
    weight = w["weight_bytes"] * n_blocks
    step = weight + kv
    emitted = n_live + int(accepted_total)
    per_tok = step / emitted if emitted else float(step)
    decode_per_tok = step / n_live if n_live else float(step)
    return {"step_bytes": step, "weight_bytes": weight, "kv_bytes": kv,
            "emitted": emitted, "bytes_per_accepted": per_tok,
            "decode_bytes_per_token": decode_per_tok,
            "amortization": (decode_per_tok / per_tok if per_tok
                             else 1.0)}


# ----------------------------------------------------------------------
# Prefix-cache traffic: prefill FLOPs and KV bytes a radix hit skips
# ----------------------------------------------------------------------


def prefix_prefill_flops(cfg, plen: int, hit: int = 0) -> int:
    """Modeled prefill FLOPs for a prompt whose first ``hit`` tokens are
    served by shared prefix-cache pages (``hit=0`` = the cold cost).

    Linear work (qkv / wo / mlp projections, 2 FLOPs per MAC) scales
    with the *suffix* token count — cached rows run no forward at all.
    Attention score+value work scales with the skipped (query, key)
    pairs: suffix queries still attend the cached prefix through the
    page gather, so only pairs whose *query* is cached drop — per
    attention layer ``4 * Hq * hd`` FLOPs per pair over
    ``T(plen) - T(hit)`` pairs, ``T(n) = n(n+1)/2``. Embedding and
    lm_head are excluded (both regimes pay them for the tokens they
    actually run, and the hit side's share is in the linear term).
    Global attention only — the engine excludes sliding-window archs
    from the prefix cache."""
    qo = cfg.n_heads * cfg.head_dim
    kvo = cfg.n_kv_heads * cfg.head_dim
    d = cfg.d_model
    gated = cfg.act in GATED_ACTS
    suffix = plen - hit
    pairs = plen * (plen + 1) // 2 - hit * (hit + 1) // 2
    total = 0
    for stage in cfg.stages():
        for blk in stage.body:
            r = stage.repeat
            if blk.mixer == "attn":
                total += r * (2 * d * (qo + 2 * kvo)    # qkv projection
                              + 2 * qo * d) * suffix    # wo projection
                total += r * 4 * qo * pairs             # scores + values
            if blk.ffn == "mlp":
                total += r * (6 if gated else 4) * d * cfg.d_ff * suffix
    return total


def prefix_cache_traffic(cfg, requests, *, page_size: int,
                         dtype_bytes: int = 2) -> dict:
    """Aggregate the prefix-cache win over a request trace.

    ``requests``: list of ``(plen, hit)`` pairs — prompt length and
    cached-prefix tokens per admission (``Engine.stats`` supplies the
    aggregates; identical-shape traces can synthesize the list).
    Returns prompt/hit token totals, the hit rate, cold vs actual
    prefill FLOPs (:func:`prefix_prefill_flops`) with their ratio, and
    ``hit_kv_bytes`` — the KV write traffic the shared pages absorb
    (rows the slot never recomputes *or* rewrites)."""
    prompt_tokens = sum(p for p, _ in requests)
    hit_tokens = sum(h for _, h in requests)
    flops_cold = sum(prefix_prefill_flops(cfg, p) for p, _ in requests)
    flops_actual = sum(prefix_prefill_flops(cfg, p, h)
                       for p, h in requests)
    n_global, _, _ = kv_layer_counts(cfg)
    row = 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
    return {
        "prompt_tokens": prompt_tokens,
        "hit_tokens": hit_tokens,
        "hit_rate": hit_tokens / prompt_tokens if prompt_tokens else 0.0,
        "flops_cold": flops_cold,
        "flops_actual": flops_actual,
        "flops_saved": flops_cold - flops_actual,
        "flops_ratio": (flops_cold / flops_actual
                        if flops_actual else float("inf")),
        "hit_kv_bytes": n_global * hit_tokens * row,
    }


# ----------------------------------------------------------------------
# Tensor-parallel serving traffic: per-device KV + weight bytes under
# head-/segment-sharding, with the cross-device all-reduce term (PR 6)
# ----------------------------------------------------------------------


def serve_tp_traffic(trace, cfg, *, n_slots: int, max_len: int,
                     page_size: int, tp: int, dtype_bytes: int = 2) -> dict:
    """Per-device modeled decode-loop bytes under tensor parallelism vs
    the single-device engine, over a recorded ``Engine.kv_trace``.

    Sharded per device (serve/placement.py):
      * KV pages — pools shard on the KV-head axis, so each device's
        block-table gathers stream ``1/tp`` of every step's KV bytes;
      * block weights — wqkv / wgi column panels and the wo / down row
        panels all split exactly ``1/tp`` (segment-wise permutation
        keeps the splits on projection boundaries);
      * an untied lm_head vocab-shards ``1/tp``; tied embeddings stay
        replicated, so the unembed panel streams in FULL on every
        device (reported honestly — it caps the ratio for tied archs).

    Cross-device bytes added per step and device (ring collectives):
      * one psum per attention output + one per MLP output — payload
        ``n_slots x d_model`` activations, ring all-reduce moves
        ``2 (tp-1)/tp`` x payload per device;
      * untied logits all-gather: ``(tp-1)/tp x n_slots x padded_vocab``
        fp32.

    Returns {"single_bytes", "per_device_bytes", "kv_bytes",
    "weight_bytes", "lm_head_bytes", "allreduce_bytes", "ratio", "tp",
    "steps"} — ``ratio`` = single / per-device, the acceptance metric.
    """
    n_global, n_local, window = kv_layer_counts(cfg)
    n_blocks = n_global + n_local
    steps = len(trace)
    kw = dict(n_global=n_global, n_local=n_local, window=window,
              n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
              dtype_bytes=dtype_bytes)
    kv = sum(paged_kv_step_bytes(lens, page_size=page_size, **kw)
             for lens in trace)
    block_w = decode_weight_traffic_cfg(
        cfg, n_slots=n_slots, dtype_bytes=dtype_bytes)["weight_bytes"]
    weights = n_blocks * block_w * steps
    vp = -(-cfg.vocab // 256) * 256                # lm.padded_vocab
    head_w = cfg.d_model * vp * dtype_bytes * steps
    single = kv + weights + head_w

    head_dev = head_w if cfg.tie_embeddings else head_w // tp
    ar = 0
    if tp > 1:
        psum = n_slots * cfg.d_model * dtype_bytes
        ar = 2 * n_blocks * (2 * (tp - 1) * psum // tp) * steps
        if not cfg.tie_embeddings:
            ar += (tp - 1) * n_slots * vp * FP32 // tp * steps
    per_device = kv // tp + weights // tp + head_dev + ar
    return {"single_bytes": single, "per_device_bytes": per_device,
            "kv_bytes": kv, "weight_bytes": weights,
            "lm_head_bytes": head_w, "allreduce_bytes": ar,
            "ratio": single / per_device if per_device else 1.0,
            "tp": tp, "steps": steps}


def swin_t_stage_cases(batch: int = 1) -> dict:
    """The Swin-T (224x224) per-stage block geometries."""
    return {
        "stage1": dict(grid_h=56, grid_w=56, c=96, heads=3, batch=batch),
        "stage2": dict(grid_h=28, grid_w=28, c=192, heads=6, batch=batch),
        "stage3": dict(grid_h=14, grid_w=14, c=384, heads=12, batch=batch),
    }
