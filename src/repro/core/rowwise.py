"""Row-wise scheduling — the paper's core contribution, adapted to TPU.

The paper decomposes conv / fully-connected / attention into a *single
dot-product primitive* on a PE array, with weights broadcast down rows
(weight-stationary) for reuse. On TPU the analogue is:

  * every dense op lowers to ONE primitive, ``rowwise_matmul`` (Pallas),
    whose grid is ordered so the weight panel stays resident in VMEM
    while activation *row* panels stream past it (= weight broadcast);
  * tile shapes are *planned* from the model's dimensions so they divide
    evenly and align to the MXU, the way the paper sizes its 12x7x4
    array to "channels are multiples of 96, spatial multiples of 7";
  * contraction dims too large for one VMEM panel are split along a
    third, innermost grid axis and accumulated in a VMEM-resident fp32
    block across the k steps (= the paper's accumulator block + adder
    tree for large C_in) — partial sums never touch HBM.

``plan_matmul`` is the scheduler: it returns the tile plan plus the
utilization this schedule achieves (useful MACs / occupied MAC slots),
mirroring the paper's >=99% utilization analysis.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# ----------------------------------------------------------------------
# Hardware geometries
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPUGeometry:
    """TPU v5e-like geometry used by the planner."""

    mxu: Tuple[int, int] = (128, 128)      # systolic array
    sublane: int = 8                       # fp32 sublanes; bf16 packs 16
    lane: int = 128
    vmem_bytes: int = 16 * 1024 * 1024     # per-core VMEM
    peak_bf16_flops: float = 197e12
    hbm_bw: float = 819e9
    ici_bw: float = 50e9                   # per link


V5E = TPUGeometry()

# dtype -> minimum (second-to-last, last) tile the TPU packs natively
_MIN_TILE = {2: (16, 128), 4: (8, 128), 1: (32, 128)}


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """A planned decomposition of an (M,K,N) matmul into row-wise tiles."""

    bm: int
    bk: int                 # K panel held in VMEM per grid step
    bn: int
    k_splits: int           # adder-tree depth (third grid axis)
    grid: Tuple[int, int, int]  # (n_tiles, m_tiles, k_splits) — k innermost
    m_pad: int
    k_pad: int
    n_pad: int
    utilization: float      # useful MACs / occupied MAC-slots
    vmem_bytes: int         # working set incl. the scratch accumulator
    flops: int
    bytes_moved: int        # modeled HBM traffic for this schedule

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_moved, 1)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_block(dim: int, target: int, align: int) -> int:
    """Largest block <= target that is a multiple of `align` and keeps
    padding low: prefer an exact divisor of the aligned dim."""
    dim_al = _round_up(dim, align)
    best = align
    b = align
    while b <= min(target, dim_al):
        if dim_al % b == 0:
            best = b
        b += align
    return best


def plan_matmul(m: int, k: int, n: int, *, dtype_bytes: int = 2,
                acc_bytes: int = 4, geom: TPUGeometry = V5E,
                target_bm: int = 256, target_bn: int = 256,
                k_max: Optional[int] = None, fused: bool = True,
                n_weights: int = 1, residual: bool = False,
                res_bytes: Optional[int] = None,
                prologue: bool = False, wide_n: bool = False,
                out_bytes: Optional[int] = None) -> TilePlan:
    """Plan a row-wise (weight-stationary) schedule for x(M,K) @ w(K,N).

    VMEM budget per grid step: x panel (bm, bk) + w panel(s) (bk, bn),
    both double-buffered, plus the fp32/int32 output block AND its
    scratch accumulator(s) (the in-kernel adder tree keeps both
    resident).

    Pipeline-fusion knobs (PR 2, see DESIGN.md §3):

      * ``n_weights``   — weight operands sharing the x panel (2 for the
                          gated gate|up kernel): charges extra w panels,
                          an extra scratch accumulator, and n_weights x
                          the weight HBM term.
      * ``residual``    — an extra (bm, bn) input operand read once,
                          priced at ``res_bytes`` (defaults to
                          ``dtype_bytes``; pass the residual's real
                          itemsize when it differs, e.g. an fp32
                          residual on the int8 path).
      * ``prologue``    — in-kernel norm: gamma/beta row operands. The
                          prologue needs the full K row per step, so
                          callers must check ``k_splits == 1`` and fall
                          back to a separate norm kernel otherwise.
      * ``wide_n``      — raise the bn target toward the whole (padded)
                          N so one activation row panel feeds every
                          fused projection (the paper's column weight
                          sharing lifted to the qkv / gate|up level).
      * ``out_bytes``   — price the single fused output write at the
                          real output dtype instead of ``acc_bytes``
                          (the legacy ``fused=False`` loop keeps fp32
                          pricing: its partials really are fp32).

    ``fused=False`` prices the seed's Python adder-tree loop instead
    (outputs round-tripping HBM once per split); kept only so
    benchmarks can report before/after traffic.
    """
    sub, lane = _MIN_TILE[dtype_bytes]
    rb = dtype_bytes if res_bytes is None else res_bytes
    if wide_n:
        target_bn = max(target_bn, min(2048, _round_up(n, lane)))
    bm = _pick_block(m, target_bm, sub)
    bn = _pick_block(n, target_bn, lane)

    # The fused kernel keeps 1 + n_weights (bm, bn) accumulator-width
    # buffers resident (output block + one scratch per weight); the
    # seed's looped kernel held only the output block, so legacy pricing
    # must not charge scratch.
    out_bufs = (1 + n_weights) if fused else 1

    def _need(bm, bk, bn):
        need = ((2 * bm * bk + n_weights * 2 * bk * bn) * dtype_bytes
                + out_bufs * bm * bn * acc_bytes)
        if residual:
            need += 2 * bm * bn * rb
        if prologue:
            need += 2 * 2 * bk * 4          # gamma/beta fp32 rows
        return need

    # Choose the K panel: as large as fits the VMEM budget.
    budget = geom.vmem_bytes - 2 * 1024 * 1024  # headroom for semaphores etc.
    if k_max is None:
        k_max = 8192
    bk = min(_round_up(k, lane), k_max)
    # A wide-N target can blow the budget on its own; give N back first
    # (down to the default 256) before shrinking the K panel, so the
    # prologue's full-K requirement survives whenever it can.
    while _need(bm, bk, bn) > budget and bn > 256:
        bn = _pick_block(n, max(bn // 2, 256), lane)
    while True:
        if _need(bm, bk, bn) <= budget or bk <= lane:
            break
        bk = max(lane, bk // 2)
    k_splits = math.ceil(k / bk)

    if fused and k_splits > 1:
        # Fused-adder-tree regime: with k innermost, the w panel is
        # re-fetched once per m tile and the x panel once per n tile —
        # bk no longer buys any HBM reuse, only bm/bn do. So shrink the
        # K panel and spend the VMEM budget on the widest (bm, bn)
        # output block instead, minimizing both re-fetch factors.
        bk = min(bk, 4 * lane)
        bm = _pick_block(m, max(target_bm, 1024), sub)
        bn = _pick_block(n, max(target_bn, 1024), lane)
        while _need(bm, bk, bn) > budget:
            if bm >= bn and bm > sub:
                bm = _pick_block(m, bm // 2, sub)
            elif bn > lane:
                bn = _pick_block(n, bn // 2, lane)
            elif bm > sub:
                bm = _pick_block(m, bm // 2, sub)
            elif bk > lane:
                bk = max(lane, bk // 2)
            else:
                break
        k_splits = math.ceil(k / bk)

    m_pad, k_pad, n_pad = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    grid = (n_pad // bn, m_pad // bm, k_splits)
    m_tiles, n_tiles = m_pad // bm, n_pad // bn

    useful = m * k * n
    occupied = m_pad * k_pad * n_pad
    flops = 2 * useful
    # HBM traffic. Activations are re-fetched once per n-tile column in
    # both regimes. Weights: fetched once when the panel is stationary
    # across m steps (k_splits == 1, index map ignores mi), once per m
    # tile when the k axis cycles under them. Outputs: the fused adder
    # tree accumulates in VMEM and writes each block exactly once; the
    # legacy loop wrote fp32 partials per split and re-read them
    # (k_splits - 1) times.
    if fused:
        w_factor = 1 if k_splits == 1 else m_tiles
        out_term = m_pad * n_pad * (acc_bytes if out_bytes is None
                                    else out_bytes)
    else:
        # Seed pricing: fp32 partials written once per split and re-read
        # (k_splits - 1) times — always at acc_bytes, whatever the
        # output dtype.
        w_factor = 1
        out_term = m_pad * n_pad * acc_bytes * (2 * k_splits - 1)
    bytes_moved = (k_pad * n_pad * dtype_bytes * w_factor * n_weights
                   + m_pad * k_pad * dtype_bytes * n_tiles
                   + out_term)
    if residual:
        bytes_moved += m_pad * n_pad * rb
    if prologue:
        bytes_moved += 2 * k_pad * 4
    return TilePlan(bm=bm, bk=bk, bn=bn, k_splits=k_splits, grid=grid,
                    m_pad=m_pad, k_pad=k_pad, n_pad=n_pad,
                    utilization=useful / occupied,
                    vmem_bytes=_need(bm, bk, bn),
                    flops=flops, bytes_moved=bytes_moved)


# ----------------------------------------------------------------------
# Model-level schedule report (the paper's Section III/IV analysis,
# generalized): walk a model's GEMMs, plan each, aggregate utilization.
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpRecord:
    name: str
    kind: str            # 'conv' | 'fc' | 'attn'
    m: int
    k: int
    n: int
    count: int = 1       # how many identical GEMMs (e.g. layers, windows)

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n * self.count


@dataclasses.dataclass
class ScheduleReport:
    ops: list
    plans: list

    @property
    def total_flops(self) -> int:
        return sum(2 * op.macs for op in self.ops)

    @property
    def utilization(self) -> float:
        useful = sum(op.macs for op in self.ops)
        occupied = sum(op.macs / max(p.utilization, 1e-12)
                       for op, p in zip(self.ops, self.plans))
        return useful / max(occupied, 1e-12)

    def dominant(self, frac: float = 0.97) -> dict:
        """FLOPs share per op kind (the paper's Fig. 2 claim)."""
        total = sum(op.macs for op in self.ops)
        shares = {}
        for op in self.ops:
            shares[op.kind] = shares.get(op.kind, 0) + op.macs / total
        return shares


def schedule_model(ops, **plan_kwargs) -> ScheduleReport:
    plans = [plan_matmul(op.m, op.k, op.n, **plan_kwargs) for op in ops]
    return ScheduleReport(ops=list(ops), plans=plans)
