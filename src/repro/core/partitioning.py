"""Logical-axis partitioning (MaxText-style) for the production mesh.

Parameters and activations are annotated with *logical* axis names;
``LOGICAL_RULES`` maps those to mesh axes. Models call
``logical_constraint`` which no-ops when no mesh is active (CPU tests)
and emits ``with_sharding_constraint`` under a mesh (dry-run / TPU).
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> mesh axis (or tuple of axes). Overridable per-run for
# the §Perf hillclimb (e.g. kv_seq -> 'model' for sequence-sharded decode).
DEFAULT_RULES = {
    # Baseline schedule (MaxText-style FSDP + sequence parallelism):
    #   activations: batch over (pod, data), sequence over model
    #   parameters:  d_model dim sharded over BOTH axes (256-way FSDP;
    #                GSPMD inserts per-layer all-gather / grad
    #                reduce-scatter), vocab over model
    #   MoE:         experts over model (EP) when divisible, else the
    #                expert d_model dim rides the FSDP sharding
    "batch": ("pod", "data", "model"),  # DP over everything that divides;
                                        # shape-aware resolve frees 'model'
                                        # for seq when batch < chips
    "seq": "model",         # activation sequence dim (sequence parallel)
    "act_embed": None,      # activation d_model dim
    "vocab_act": "model",   # activation vocab dim (logits)
    "embed": ("data", "model"),  # parameter d_model dim (FSDP)
    "vocab": "model",
    # Fused sibling-projection panel dims (PR 4): 'qkv' names the N axis
    # of the stored wqkv / wkv leaves (q|k|v column panels concatenated
    # at init), 'ffn' the wgi gate|up panel. Any mesh axis assigned here
    # must divide EVERY segment of the fused panel (q, k, v / gate, up),
    # not just the total width — otherwise a shard boundary would fall
    # inside one projection and decode's output slicing would cross
    # shards. The baseline schedule keeps both replicated (FSDP shards
    # the K axis via 'embed' instead).
    "qkv": None,
    "ffn": None,
    "experts": "model",     # expert-parallel stacked expert dim
    "heads": "model",       # activation heads dim
    "kv_heads": None,
    "kv_seq": None,         # KV-cache sequence dim
    "layers": None,
    "conv": None,
}

_ACTIVE: dict = {"mesh": None, "rules": dict(DEFAULT_RULES),
                 "tp_axis": None}


# ----------------------------------------------------------------------
# Tensor-parallel shard context (serving). Unlike the GSPMD mesh above,
# this marks code being traced INSIDE a shard_map body whose params are
# manually segment-/head-sharded over one mesh axis: every tensor the
# model sees is the local shard, and the row-parallel output
# projections (attention wo, MLP down) produce K-partial sums that the
# layers finish with ``tp_reduce`` before adding bias/residual.
# ``serve/placement.py`` activates it while tracing the engine's jitted
# entry points; with no axis active every hook is a no-op, so the
# single-device paths are untouched.
# ----------------------------------------------------------------------


@contextlib.contextmanager
def tp_shard(axis: str):
    prev = _ACTIVE["tp_axis"]
    _ACTIVE["tp_axis"] = axis
    try:
        yield
    finally:
        _ACTIVE["tp_axis"] = prev


def tp_axis() -> Optional[str]:
    """The active tensor-parallel mesh axis, or None outside TP tracing."""
    return _ACTIVE["tp_axis"]


def tp_reduce(y):
    """psum a K-partial matmul output over the TP axis (no-op without
    one). Must run BEFORE any bias/residual add: folding those into a
    partial shard's epilogue would multiply them by the shard count."""
    ax = _ACTIVE["tp_axis"]
    return jax.lax.psum(y, ax) if ax is not None else y


def set_rules(overrides: dict) -> None:
    _ACTIVE["rules"].update(overrides)


def get_rules() -> dict:
    return dict(_ACTIVE["rules"])


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = dict(_ACTIVE)
    _ACTIVE["mesh"] = mesh
    if rules:
        _ACTIVE["rules"] = {**DEFAULT_RULES, **rules}
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _ACTIVE.update(prev)


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE["mesh"]


def _mesh_axes(mesh: Mesh):
    return set(mesh.axis_names)


def resolve(spec_names: Tuple[Optional[str], ...],
            mesh: Optional[Mesh] = None, shape=None) -> P:
    """Logical names -> PartitionSpec under the active rules + mesh.

    Shape-aware: when `shape` is given, axes that do not divide the dim
    (cumulatively) are dropped *before* being marked used, so e.g.
    batch=(pod,data,model) on a 256-batch frees 'model' for the seq dim
    on the 512-chip mesh. This is what lets one logical profile serve
    every (arch x shape x mesh) cell."""
    mesh = mesh or _ACTIVE["mesh"]
    rules = _ACTIVE["rules"]
    axes = _mesh_axes(mesh) if mesh is not None else None
    sizes = (dict(zip(mesh.axis_names, mesh.devices.shape))
             if mesh is not None else {})
    # axes already manual in an enclosing shard_map may not appear in
    # GSPMD constraints inside the body (e.g. 'pod' under compression)
    manual = set()
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and getattr(am, "axis_names", None):
            manual = set(getattr(am, "manual_axes", ()) or ())
    except Exception:
        pass
    out = []
    used = set(manual)

    for i, name in enumerate(spec_names):
        ax = rules.get(name) if name else None
        dim = shape[i] if shape is not None and i < len(shape) else None
        cand = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        kept = []
        prod = 1
        for a in cand:
            if a is None or a in used:
                continue
            if axes is not None and a not in axes:
                continue
            if dim is not None and dim % (prod * sizes.get(a, 1)) != 0:
                continue
            kept.append(a)
            prod *= sizes.get(a, 1)
        used.update(kept)
        out.append(tuple(kept) if len(kept) > 1
                   else (kept[0] if kept else None))
    return P(*out)


def logical_constraint(x, *names):
    """with_sharding_constraint by logical names; no-op without a mesh.
    Axes that do not divide the dim evenly are dropped (never force GSPMD
    into involuntary resharding/replication)."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    spec = resolve(tuple(names), mesh, shape=x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *names, shape=None) -> NamedSharding:
    return NamedSharding(mesh, resolve(tuple(names), mesh, shape=shape))


def constrain_tree(tree, logical_spec_tree):
    """with_sharding_constraint a whole tree by logical specs (no-op
    without a mesh). Used to pin gradients to the parameter sharding so
    GSPMD emits reduce-scatters instead of full all-reduces."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return tree
    shardings = tree_shardings(mesh, logical_spec_tree, like=tree)
    return jax.tree.map(jax.lax.with_sharding_constraint, tree, shardings)


# ----------------------------------------------------------------------
# Logical-spec trees. Initializers return (params, logical_specs) with
# identical tree structure; this resolves a whole tree to shardings.
# ----------------------------------------------------------------------


def _is_spec_leaf(x) -> bool:
    """A logical spec leaf: plain tuple of axis names (not a NamedTuple)."""
    return (isinstance(x, tuple) and not hasattr(x, "_fields")
            and all(e is None or isinstance(e, str) for e in x))


def tree_shardings(mesh: Mesh, logical_tree, like=None):
    """Resolve a logical-spec tree to NamedShardings. When `like` (a tree
    of ShapeDtypeStructs/arrays) is given, shardings are shape-checked
    and non-divisible axes dropped per-dimension."""
    def one(names, ref=None):
        return NamedSharding(mesh, resolve(
            tuple(names), mesh, shape=ref.shape if ref is not None
            else None))

    if like is None:
        return jax.tree.map(one, logical_tree, is_leaf=_is_spec_leaf)
    flat_specs, treedef = jax.tree_util.tree_flatten(
        logical_tree, is_leaf=_is_spec_leaf)
    flat_like = treedef.flatten_up_to(like)
    return treedef.unflatten(
        [one(s, r) for s, r in zip(flat_specs, flat_like)])
