"""Version shims for the supported jax range (>=0.4.30).

``jax.shard_map`` became a top-level API (with ``check_vma`` /
``axis_names``) after 0.4.x; on 0.4.x the same machinery lives at
``jax.experimental.shard_map.shard_map`` with the older ``check_rep`` /
``auto`` spelling. Callers use this module's :func:`shard_map` with the
new-style kwargs and run on both.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, *, axis_names=None,
              check_vma=None):
    """New-style ``jax.shard_map`` signature on any supported jax.

    axis_names: mesh axes to shard manually (others stay GSPMD-auto);
    None means all axes manual. check_vma: replication checking (the
    pre-0.5 name is check_rep).
    """
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)

    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {}
    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    if axis_names is not None:
        kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)
