"""Core configuration types for the repro framework.

Everything downstream (models, launch, dry-run, roofline) is driven by two
frozen dataclasses: ``ModelConfig`` (an architecture) and ``ShapeSpec`` (an
input-shape cell). Architectures are *stage-compiled*: a config lowers to a
list of ``Stage``s, each of which is a ``lax.scan`` over a homogeneous
super-block body, so HLO size is independent of depth.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


# Activations whose MLP is the gated two-matmul front half (SwiGLU /
# GeGLU): the model layer stores wg|wi as one fused ``wgi`` leaf and the
# traffic model prices the dual-weight kernel. Single source of truth —
# models/mlp.py and core/block_traffic.py both branch on it.
GATED_ACTS = ("silu", "geglu")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (token-choice routing)."""

    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    n_shared: int = 0              # always-on shared experts (qwen2-moe)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) configuration."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 ("Finch") time-mix configuration."""

    head_dim: int = 64
    chunk: int = 128
    decay_lora: int = 64           # rank of data-dependent decay LoRA
    tokenshift_lora: int = 32


@dataclasses.dataclass(frozen=True)
class PagingConfig:
    """Paged-KV serving geometry (vLLM-style block tables).

    The serving engine carves each attention layer's KV storage into a
    global pool of fixed-size pages ``(n_pages + n_slots, page_size,
    Hkv, hd)`` and maps every slot's logical positions onto physical
    pages through a per-slot block table. Physical page ``n_pages +
    slot`` is the slot's private *scratch page*: idle and mid-prefill
    slots' tables point at it so lockstep decode writes land in storage
    nobody reads — and, being per-slot, never serialize on one page.

    ``n_pages == 0`` means "size for full occupancy": the engine
    allocates ``n_slots * ceil(max_len / page_size)`` real pages, i.e.
    the same capacity as the dense lockstep caches; smaller values
    oversubscribe and the engine defers admissions until pages free up.

    ``prefill_chunk > 0`` enables *chunked prefill*: prompts longer than
    the chunk split into successive row panels processed across engine
    steps, interleaved with decode — the monolithic largest-bucket
    prefill program no longer stalls co-resident decode slots (the TTFT
    cliff). The chunk must sit on the bucket ladder (a power of two) so
    compiled chunk shapes stay bounded, and requires a bucketing-capable
    arch (pure causal attention).
    """

    page_size: int = 16            # tokens per KV page
    n_pages: int = 0               # real pages per layer pool (0 => full)
    min_bucket: int = 16           # smallest prefill padding bucket
    prefill_chunk: int = 0         # chunked-prefill panel size (0 => off)
    # Slice the decode block table to the batch's max live pages,
    # rounded up to a power of two, so executed gather volume tracks
    # live-page traffic instead of always reading max_pages entries.
    # Costs up to log2(max_pages) extra compiled decode programs (one
    # per table width), so it is opt-in.
    table_width_bucketing: bool = False
    # Radix-tree prefix cache over token prefixes: admission maps fully
    # shared prompt pages straight into the new slot's block table
    # (refcount++, zero prefill FLOPs) and chunked prefill processes
    # only the uncached suffix. Requires prefill_chunk > 0 (suffixes
    # replay through the chunk ladder, keeping the compile bound) and a
    # bucketing-capable, all-global-attention arch (sliding-window ring
    # writes would clobber shared pages); silently off otherwise.
    prefix_cache: bool = False
    # Sarathi-style cap on prefill tokens advanced per engine step
    # across mid-prefill slots (0 => unbounded). The head of the chunk
    # queue always advances, so prefill can't fully starve.
    prefill_token_budget: int = 0
    # Self-speculative decode: max draft tokens per slot per step
    # (0 => off). Drafts come from a host-side prompt-lookup n-gram
    # drafter (serve/spec.py); a batched verify step scores the panel
    # through the chunk kernels and writes only accepted rows. Panel
    # widths pad up the documented ``paging.spec_ladder`` so the
    # compile bound grows by len(ladder) programs exactly. Requires a
    # bucketing-capable arch, and is mutually exclusive with
    # table_width_bucketing (the width ladder would multiply the
    # k-ladder; speculative steps ship full-width tables instead).
    speculate_k: int = 0


@dataclasses.dataclass(frozen=True)
class BlockDef:
    """One layer inside a stage body.

    mixer  : 'attn' | 'mamba2' | 'rwkv6' | 'none'
    ffn    : 'mlp' | 'moe' | 'rwkv6_cmix' | 'none'
    window : 0 => global attention; >0 => sliding-window (local) attention
    shared : True => parameters are NOT stacked over scan repeats (zamba2's
             shared attention block); they are closed over instead.
    cross_attn : True => decoder block with cross-attention (whisper).
    """

    mixer: str = "attn"
    ffn: str = "mlp"
    window: int = 0
    shared: bool = False
    cross_attn: bool = False


@dataclasses.dataclass(frozen=True)
class Stage:
    """``repeat`` scan iterations over ``body`` (a tuple of BlockDefs)."""

    repeat: int
    body: Tuple[BlockDef, ...]

    @property
    def n_layers(self) -> int:
        return self.repeat * len(self.body)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|hybrid|vlm|audio|ssm|vision
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    act: str = "silu"              # silu => SwiGLU MLP; gelu => GELU MLP
    norm: str = "rms"              # rms | layer
    rope: str = "default"          # default | mrope | none
    rope_theta: float = 10_000.0
    # sliding-window pattern: e.g. gemma3 is 5 local : 1 global
    pattern_local: int = 0         # local layers per pattern group
    pattern_global: int = 0        # global layers per pattern group
    local_window: int = 0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # hybrid (zamba2): attn block shared every `hybrid_period` ssm layers
    hybrid_period: int = 0
    # encoder-decoder (whisper)
    encdec: bool = False
    n_enc_layers: int = 0
    cross_len: int = 1500          # encoder output length (audio frames)
    # modality frontend stub: 'none' | 'vision' | 'audio'
    frontend: str = "none"
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # long-context capability flag (drives long_500k applicability)
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    # ------------------------------------------------------------------
    # Stage compilation: config -> homogeneous scan stages
    # ------------------------------------------------------------------
    def stages(self) -> Tuple[Stage, ...]:
        if self.family == "ssm" and self.rwkv is not None:
            blk = BlockDef(mixer="rwkv6", ffn="rwkv6_cmix")
            return (Stage(self.n_layers, (blk,)),)

        if self.family == "hybrid":
            period = self.hybrid_period or 6
            ssm_blk = BlockDef(mixer="mamba2", ffn="none")
            attn_blk = BlockDef(mixer="attn", ffn="mlp", shared=True)
            n_groups = self.n_layers // period
            tail = self.n_layers - n_groups * period
            stages = [Stage(n_groups, (ssm_blk,) * (period - 1) + (attn_blk,))]
            if tail:
                stages.append(Stage(tail, (ssm_blk,)))
            return tuple(stages)

        ffn = "moe" if self.moe is not None else "mlp"
        if self.pattern_local:
            group = self.pattern_local + self.pattern_global
            n_groups = self.n_layers // group
            tail = self.n_layers - n_groups * group
            local = BlockDef(mixer="attn", ffn=ffn, window=self.local_window)
            glob = BlockDef(mixer="attn", ffn=ffn, window=0)
            body = (local,) * self.pattern_local + (glob,) * self.pattern_global
            stages = [Stage(n_groups, body)]
            if tail:
                stages.append(Stage(tail, (local,)))
            return tuple(stages)

        blk = BlockDef(mixer="attn", ffn=ffn,
                       cross_attn=self.encdec)
        return (Stage(self.n_layers, (blk,)),)

    def enc_stages(self) -> Tuple[Stage, ...]:
        assert self.encdec
        blk = BlockDef(mixer="attn", ffn="mlp")
        return (Stage(self.n_enc_layers, (blk,)),)

    # ------------------------------------------------------------------
    # Parameter counting (used for MODEL_FLOPS = 6*N*D roofline term)
    # ------------------------------------------------------------------
    def param_counts(self) -> dict:
        """Analytic parameter counts: total and per-token-active."""
        d, hd = self.d_model, self.head_dim
        q_out = self.n_heads * hd
        kv_out = self.n_kv_heads * hd

        def attn_params():
            return d * q_out + 2 * d * kv_out + q_out * d

        def mlp_params(d_ff):
            n_mats = 3 if self.act in GATED_ACTS else 2
            return n_mats * d * d_ff

        total = active = 0
        for stage in self.stages():
            for blk in stage.body:
                mult = 1 if blk.shared else stage.repeat
                p = 0
                if blk.mixer == "attn":
                    p += attn_params() + 2 * d  # + norm
                    if blk.cross_attn:
                        p += attn_params() + d
                elif blk.mixer == "mamba2":
                    s = self.ssm
                    d_in = s.expand * d
                    p += 2 * d_in * d + d_in * 2 * s.d_state  # in/out/BC proj
                    p += d_in * s.d_conv + 2 * (d_in // s.head_dim) + d
                elif blk.mixer == "rwkv6":
                    r = self.rwkv
                    p += 4 * d * d + d * r.decay_lora * 2 + 6 * d + 2 * d
                a = p  # mixer params are always active
                if blk.ffn == "mlp":
                    m = mlp_params(self.d_ff) + d
                    p += m
                    a += m
                elif blk.ffn == "moe":
                    mo = self.moe
                    e = mlp_params(mo.d_ff)
                    p += mo.n_experts * e + d * mo.n_experts + d
                    p += mo.n_shared * mlp_params(mo.d_ff)
                    a += (mo.top_k + mo.n_shared) * e + d * mo.n_experts + d
                elif blk.ffn == "rwkv6_cmix":
                    m = int(2 * d * self.d_ff) + d
                    p += m
                    a += m
                total += mult * p
                active += mult * a
        embed = self.vocab * d
        total += embed + d
        active += embed + d
        if not self.tie_embeddings:
            total += embed
            active += embed
        if self.encdec:
            for stage in self.enc_stages():
                for _blk in stage.body:
                    p = attn_params() + mlp_params(self.d_ff) + 3 * d
                    total += stage.repeat * p
                    active += stage.repeat * p
        return {"total": total, "active": active}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell. kind: train | prefill | decode."""

    name: str
    kind: str
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        # tokens processed per step: full seq for train/prefill, 1/seq for decode
        if self.kind == "decode":
            return self.global_batch
        return self.global_batch * self.seq_len
