"""Int8 quantization — the paper uses 8-bit weights AND activations.

Symmetric int8: per-output-channel scales for weights (computed offline),
per-row dynamic scales for activations (computed on the fly, the way the
ASIC quantizes between layers). Used by the int8 path of the row-wise
matmul kernel and by the serving engine (weight-only or W8A8).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

INT8_MAX = 127.0


def quantize_per_channel(w: jnp.ndarray, axis: int = 0
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize weights per output channel. Returns (int8 w, fp32 scale).

    ``axis`` is the *contraction* axis; scales are per remaining channel.
    """
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_per_row(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dynamic per-row activation quantization (rows = last-but-one dim)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(acc_i32: jnp.ndarray, x_scale: jnp.ndarray,
               w_scale: jnp.ndarray) -> jnp.ndarray:
    return acc_i32.astype(jnp.float32) * x_scale * w_scale


def quantize_tree(params, predicate=None):
    """Weight-only quantize every >=2D leaf of a param tree. Returns a
    tree of (int8, scale) pairs for matmul weights, passthrough others.

    Scales are per output channel, so quantizing a *fused* projection
    leaf (wq|wk|wv or wg|wi stored pre-concatenated, PR 4) yields
    exactly the concatenation of the per-part scales: the int8 panel
    and its scales arrive pre-fused, no per-call scale concat needed.
    """
    import jax

    def q(path, leaf):
        if leaf.ndim >= 2 and (predicate is None or predicate(path, leaf)):
            qw, s = quantize_per_channel(leaf, axis=leaf.ndim - 2)
            return {"q": qw, "s": s}
        return leaf

    return jax.tree_util.tree_map_with_path(q, params)


LM_WEIGHT_KEYS = frozenset({
    "embed", "lm_head", "wqkv", "wkv", "wq", "wk", "wv", "wgi", "wg",
    "wi", "wo"})


def lm_weight_predicate(path, leaf) -> bool:
    """Predicate for :func:`quantize_tree` on LM trees: quantize only
    the matmul projection / embedding leaves. Scan-stacked norm gains
    are (R, d) and pass the >=2D check, but they are not weight
    matrices — quantizing them breaks both accuracy and the stacked
    leading axis (their scales would collapse it to 1)."""
    key = getattr(path[-1], "key", None)
    return key in LM_WEIGHT_KEYS


def is_quantized(leaf) -> bool:
    """True for a weight-only int8 leaf produced by :func:`quantize_tree`."""
    return isinstance(leaf, dict) and "q" in leaf and "s" in leaf


def resolve_weight(w, dtype=None):
    """Materialize a weight leaf for an fp matmul: arrays pass through;
    weight-only int8 ``{"q", "s"}`` leaves dequantize to ``dtype`` (the
    serving engine's weight-only path — exact, the scales are the ones
    the quantizer chose)."""
    if is_quantized(w):
        out = w["q"].astype(jnp.float32) * w["s"]
        return out.astype(dtype) if dtype is not None else out
    return w
