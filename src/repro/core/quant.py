"""Int8 quantization — the paper uses 8-bit weights AND activations.

Symmetric int8: per-output-channel scales for weights (computed offline),
per-row dynamic scales for activations (computed on the fly, the way the
ASIC quantizes between layers). Used by the int8 path of the row-wise
matmul kernel and by the serving engine (weight-only or W8A8).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

INT8_MAX = 127.0


def quantize_per_channel(w: jnp.ndarray, axis: int = 0
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize weights per output channel. Returns (int8 w, fp32 scale).

    ``axis`` is the *contraction* axis; scales are per remaining channel.
    """
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_per_row(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dynamic per-row activation quantization (rows = last-but-one dim)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(acc_i32: jnp.ndarray, x_scale: jnp.ndarray,
               w_scale: jnp.ndarray) -> jnp.ndarray:
    return acc_i32.astype(jnp.float32) * x_scale * w_scale


def quantize_tree(params, predicate=None):
    """Weight-only quantize every >=2D leaf of a param tree. Returns a
    tree of (int8, scale) pairs for matmul weights, passthrough others."""
    import jax

    def q(path, leaf):
        if leaf.ndim >= 2 and (predicate is None or predicate(path, leaf)):
            qw, s = quantize_per_channel(leaf, axis=leaf.ndim - 2)
            return {"q": qw, "s": s}
        return leaf

    return jax.tree_util.tree_map_with_path(q, params)
