"""Faithful analytical model of the paper's ASIC (the reproduction target).

Reimplements the paper's PE array — 12 blocks x 7 rows x 4 MACs = 336
MACs @ 600 MHz, 8-bit W/A — and its row-wise scheduling rules:

  * conv 4x4x3: the 48-weight kernel is spread over all 12 blocks
    (3 channels x 4 blocks), 7 rows produce 7 spatial outputs/cycle
    => 448 cycles per output channel for a 224x224 image (Sec. IV-C);
  * fully-connected: 48 input channels per cycle (12 blocks x 4 MACs),
    7 outputs per pass (7 rows), accumulated over ceil(K/48) cycles
    (Sec. IV-D: 96 channels => 7 outputs every 2 cycles);
  * attention (QK^T, AV): Q is broadcast as the weight, K is the input;
    only 8 of 12 blocks are used (Sec. IV-E) => 32 K-lanes/cycle and
    8/12 peak utilization for these ops.

Walking Swin-T through these rules reproduces the paper's claims:
403.2 GOPS peak (Table III), ~22.4 ms / 44.5 img/s per 224x224 image
(Table IV), overall utilization >= 99% (Sec. V), and the Fig. 2
FLOPs/parameter distribution (>=97% FLOPs and >=83% params in FC).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

from repro.configs.swin_t import SwinConfig, ViTConfig
from repro.core.rowwise import OpRecord


@dataclasses.dataclass(frozen=True)
class ASICGeometry:
    blocks: int = 12
    rows: int = 7
    macs_per_row: int = 4
    clock_hz: float = 600e6
    attn_blocks: int = 8          # Sec. IV-E: attention uses 8 blocks

    @property
    def macs(self) -> int:
        return self.blocks * self.rows * self.macs_per_row  # 336

    @property
    def peak_gops(self) -> float:
        return self.macs * 2 * self.clock_hz / 1e9          # 403.2


ASIC = ASICGeometry()


def op_cycles(op: OpRecord, geom: ASICGeometry = ASIC) -> int:
    """Cycle count for one GEMM under the paper's row-wise schedule."""
    if op.kind == "attn":
        k_lanes = geom.attn_blocks * geom.macs_per_row      # 32
    else:
        k_lanes = geom.blocks * geom.macs_per_row           # 48
    per = op.n * math.ceil(op.k / k_lanes) * math.ceil(op.m / geom.rows)
    return per * op.count


@dataclasses.dataclass
class ASICReport:
    ops: List[OpRecord]
    cycles: int
    geom: ASICGeometry

    @property
    def total_macs(self) -> int:
        return sum(op.macs for op in self.ops)

    @property
    def time_s(self) -> float:
        return self.cycles / self.geom.clock_hz

    @property
    def images_per_s(self) -> float:
        return 1.0 / self.time_s

    @property
    def utilization(self) -> float:
        return self.total_macs / (self.geom.macs * self.cycles)

    @property
    def achieved_gops(self) -> float:
        return 2 * self.total_macs / self.time_s / 1e9

    def flops_shares(self) -> dict:
        total = self.total_macs
        out = {}
        for op in self.ops:
            out[op.kind] = out.get(op.kind, 0.0) + op.macs / total
        return out


def run_asic(ops: List[OpRecord], geom: ASICGeometry = ASIC) -> ASICReport:
    return ASICReport(ops=list(ops), geom=geom,
                      cycles=sum(op_cycles(op, geom) for op in ops))


# ----------------------------------------------------------------------
# Swin / ViT GEMM walks (shared by the ASIC model, the TPU row-wise
# scheduler, and the Fig. 2 benchmark)
# ----------------------------------------------------------------------


def swin_ops(cfg: SwinConfig) -> List[OpRecord]:
    """Decompose Swin into (M, K, N) GEMMs, layer by layer."""
    ops: List[OpRecord] = []
    res = cfg.img_size // cfg.patch
    c = cfg.embed_dim
    # patch-embed conv: (H/4*W/4) outputs, K = 4*4*3, N = embed_dim
    ops.append(OpRecord("patch_embed", "conv",
                        m=res * res, k=cfg.patch * cfg.patch * cfg.in_chans,
                        n=c))
    for si, (depth, heads) in enumerate(zip(cfg.depths, cfg.num_heads)):
        tokens = res * res
        n_windows = (res // cfg.window) ** 2
        wt = cfg.window * cfg.window          # tokens per window (49)
        hd = c // heads
        for _ in range(depth):
            ops.append(OpRecord(f"s{si}.qkv", "fc", m=tokens, k=c, n=3 * c))
            ops.append(OpRecord(f"s{si}.qk", "attn", m=wt, k=hd, n=wt,
                                count=n_windows * heads))
            ops.append(OpRecord(f"s{si}.av", "attn", m=wt, k=wt, n=hd,
                                count=n_windows * heads))
            ops.append(OpRecord(f"s{si}.proj", "fc", m=tokens, k=c, n=c))
            mlp = int(cfg.mlp_ratio * c)
            ops.append(OpRecord(f"s{si}.mlp1", "fc", m=tokens, k=c, n=mlp))
            ops.append(OpRecord(f"s{si}.mlp2", "fc", m=tokens, k=mlp, n=c))
        if si < len(cfg.depths) - 1:
            # patch merging: (res/2)^2 tokens, 4C -> 2C
            ops.append(OpRecord(f"s{si}.merge", "fc",
                                m=(res // 2) ** 2, k=4 * c, n=2 * c))
            res //= 2
            c *= 2
    ops.append(OpRecord("head", "fc", m=1, k=c, n=cfg.num_classes))
    return ops


def swin_params(cfg: SwinConfig) -> dict:
    """Parameter counts by category (conv / fc / attn) for Fig. 2."""
    conv = cfg.patch * cfg.patch * cfg.in_chans * cfg.embed_dim
    fc = 0
    attn = 0
    res = cfg.img_size // cfg.patch
    c = cfg.embed_dim
    for si, (depth, heads) in enumerate(zip(cfg.depths, cfg.num_heads)):
        for _ in range(depth):
            fc += 3 * c * c + c * c                      # qkv + proj
            mlp = int(cfg.mlp_ratio * c)
            fc += c * mlp + mlp * c
            attn += heads * (2 * cfg.window - 1) ** 2    # rel-pos bias
        if si < len(cfg.depths) - 1:
            fc += 4 * c * 2 * c
            c *= 2
    fc += c * cfg.num_classes
    return {"conv": conv, "fc": fc, "attn": attn}


def vit_ops(cfg: ViTConfig) -> List[OpRecord]:
    ops: List[OpRecord] = []
    tokens = (cfg.img_size // cfg.patch) ** 2
    c = cfg.embed_dim
    hd = c // cfg.num_heads
    ops.append(OpRecord("patch_embed", "conv", m=tokens,
                        k=cfg.patch * cfg.patch * cfg.in_chans, n=c))
    seq = tokens + 1
    for i in range(cfg.depth):
        ops.append(OpRecord(f"l{i}.qkv", "fc", m=seq, k=c, n=3 * c))
        ops.append(OpRecord(f"l{i}.qk", "attn", m=seq, k=hd, n=seq,
                            count=cfg.num_heads))
        ops.append(OpRecord(f"l{i}.av", "attn", m=seq, k=seq, n=hd,
                            count=cfg.num_heads))
        ops.append(OpRecord(f"l{i}.proj", "fc", m=seq, k=c, n=c))
        mlp = int(cfg.mlp_ratio * c)
        ops.append(OpRecord(f"l{i}.mlp1", "fc", m=seq, k=c, n=mlp))
        ops.append(OpRecord(f"l{i}.mlp2", "fc", m=seq, k=mlp, n=c))
    ops.append(OpRecord("head", "fc", m=1, k=c, n=cfg.num_classes))
    return ops
