"""Gradient compression for slow (cross-pod) links: int8 + error feedback.

The (pod, data, model) mesh has a bandwidth hierarchy: intra-pod ICI is
fast; the cross-pod axis is the slow link. When enabled, the train step
runs as a shard_map over 'pod' (data/model stay GSPMD-auto inside): each
pod computes its own gradient, then the cross-pod mean runs in int8 with
an error-feedback residual (EF-SGD, Karimireddy et al. — convergence is
preserved despite the biased compressor). 4x less cross-pod traffic.

These helpers are called INSIDE the shard_map body (`axis` is a manual
mesh axis there).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_pmean(g: jnp.ndarray, axis: str) -> Tuple[jnp.ndarray,
                                                         jnp.ndarray]:
    """int8 mean-all-reduce of one leaf over `axis`.

    Returns (mean, local_dequantized) — the caller forms the error
    residual as (g - local_dequantized).

    Wire cost: int8 payload (4x smaller than f32) + one f32 scale.
    The int8 payload is summed in int32 (the hardware collective);
    per-shard scales are averaged, and error feedback absorbs the
    scale-mismatch bias.
    """
    q, scale = _quantize(g)
    deq_local = q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    scale_mean = jax.lax.pmean(scale, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    mean = total.astype(jnp.float32) * scale_mean / n
    return mean, deq_local


def compressed_pmean_tree(grads: Any, residual: Any, axis: str
                          ) -> Tuple[Any, Any]:
    """Error-feedback int8 pmean over a whole gradient tree.

    residual: error-feedback buffer (same structure, fp32).
    Returns (mean_grads, new_residual).
    """
    def per_leaf(g, r):
        gf = g.astype(jnp.float32) + r
        mean, deq = compressed_pmean(gf, axis)
        return mean.astype(g.dtype), gf - deq

    flat_g, td = jax.tree.flatten(grads)
    flat_r = td.flatten_up_to(residual)
    out = [per_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (td.unflatten([o[0] for o in out]),
            td.unflatten([o[1] for o in out]))


def init_residual(grads_or_params: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_or_params)
