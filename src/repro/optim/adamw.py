"""AdamW in pure JAX (no optax in this environment).

fp32 first/second moments regardless of param dtype (mixed-precision
training standard); ZeRO-1-style sharding falls out of giving optimizer
state the same logical specs as the parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def state_specs(param_specs) -> AdamWState:
    """Optimizer state inherits parameter logical specs (ZeRO-1)."""
    return AdamWState(step=(), mu=param_specs, nu=param_specs)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply(cfg: AdamWConfig, state: AdamWState, params, grads, lr_scale=1.0):
    """-> (new_params, new_state, grad_norm)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * (
            p.astype(jnp.float32))
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm


def cosine_schedule(step, *, warmup: int, total: int, min_frac: float = 0.1):
    """LR multiplier: linear warmup then cosine decay to min_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
