"""Architecture registry: ``--arch <id>`` resolves through ARCHS."""
from __future__ import annotations

from repro.configs import (deepseek_7b, gemma3_27b, granite_20b,
                           internlm2_20b, phi35_moe, qwen2_moe, qwen2_vl,
                           rwkv6_3b, whisper_base, zamba2)
from repro.configs.shapes import SHAPES, SMOKE_SHAPES
from repro.core.types import ModelConfig

_MODULES = {
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "qwen2-moe-a2.7b": qwen2_moe,
    "zamba2-1.2b": zamba2,
    "qwen2-vl-2b": qwen2_vl,
    "granite-20b": granite_20b,
    "deepseek-7b": deepseek_7b,
    "gemma3-27b": gemma3_27b,
    "internlm2-20b": internlm2_20b,
    "whisper-base": whisper_base,
    "rwkv6-3b": rwkv6_3b,
}

ARCHS = {name: mod.CONFIG for name, mod in _MODULES.items()}
REDUCED = {name: mod.reduced for name, mod in _MODULES.items()}

# Shape-cell applicability (skips documented in DESIGN.md §5):
#  - long_500k only for sub-quadratic archs
#  - (no encoder-only archs in this pool, so no decode skips)


def cell_applicable(arch: str, shape: str) -> bool:
    cfg = ARCHS[arch]
    if shape == "long_500k" and not cfg.subquadratic:
        return False
    return True


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_reduced(arch: str) -> ModelConfig:
    return REDUCED[arch]()


def all_cells(include_skipped: bool = False):
    for arch in ARCHS:
        for shape in SHAPES:
            if include_skipped or cell_applicable(arch, shape):
                yield arch, shape


__all__ = ["ARCHS", "REDUCED", "SHAPES", "SMOKE_SHAPES", "get_config",
           "get_reduced", "cell_applicable", "all_cells"]
