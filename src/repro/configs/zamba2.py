"""zamba2-1.2b [arXiv:2411.15242] — hybrid Mamba2 + shared attention blocks.

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
The shared transformer block (full attention + MLP, single weight copy)
is applied every 6th layer, per the Zamba2 shared-block design.
"""
from repro.core.types import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    act="gelu",
    norm="rms",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
    hybrid_period=6,
    subquadratic=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", n_layers=5, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, act="gelu", norm="rms",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32),
        hybrid_period=3, subquadratic=True,
    )
