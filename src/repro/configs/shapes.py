"""The four assigned input-shape cells (LM-family shapes)."""
from repro.core.types import ShapeSpec

SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=4_096, global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32_768, global_batch=32),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32_768, global_batch=128),
    "long_500k": ShapeSpec("long_500k", "decode", seq_len=524_288, global_batch=1),
}

# Smoke-scale variants of the same kinds (used by tests; tiny).
SMOKE_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=64, global_batch=2),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=64, global_batch=2),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=64, global_batch=2),
    "long_500k": ShapeSpec("long_500k", "decode", seq_len=128, global_batch=1),
}
