"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) d_ff=1408 vocab=151936, MoE 60 routed top-4
+ 4 shared experts.
"""
from repro.core.types import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    act="silu",
    norm="rms",
    moe=MoEConfig(n_experts=60, top_k=4, d_ff=1408, n_shared=4),
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=48, vocab=256, act="silu", norm="rms",
        moe=MoEConfig(n_experts=6, top_k=2, d_ff=48, n_shared=1,
                      capacity_factor=4.0),
        tie_embeddings=False,
    )
