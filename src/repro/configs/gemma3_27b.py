"""gemma3-27b [hf:google/gemma-3 family] — 5:1 local:global attention, 128k.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
Local layers use a 1024-token sliding window (ring-buffer KV cache at
decode); every 6th layer is global. The local/global mix makes the
long_500k decode cell tractable (only ~1/6 of layers carry the full
cache; global decode attention is sequence-sharded over the model axis).
"""
from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab=262144,
    head_dim=128,
    act="geglu",
    norm="rms",
    pattern_local=5,
    pattern_global=1,
    local_window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    subquadratic=True,  # 5:1 sliding window => sub-quadratic in practice
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense", n_layers=8, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
        act="gelu", norm="rms", pattern_local=2, pattern_global=1,
        local_window=16, subquadratic=True,
    )
