"""whisper-base [arXiv:2212.04356] — encoder-decoder, conv frontend stub.

6L (enc) + 6L (dec), d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
The audio conv frontend is a stub per the brief: input_specs() provides
precomputed frame embeddings (B, 1500, 512). Cross-attention context is
fixed at 1500 frames. Decode cells lower the requested KV length
mechanically (real Whisper caps text at 448; noted in DESIGN.md).
"""
from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    act="gelu",
    norm="layer",
    rope="none",           # whisper uses learned/sinusoidal abs positions
    encdec=True,
    n_enc_layers=6,
    cross_len=1500,
    frontend="audio",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, act="gelu",
        norm="layer", rope="none", encdec=True, n_enc_layers=2,
        cross_len=30, frontend="audio",
    )
