"""rwkv6-3b "Finch" [arXiv:2404.05892] — attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536. No attention heads; the WKV6
recurrence uses 64-dim heads (2560/64 = 40 heads).
"""
from repro.core.types import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # wkv heads = d_model / head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    act="relu",            # rwkv channel-mix uses relu^2
    norm="layer",
    rope="none",
    rwkv=RWKVConfig(head_dim=64, decay_lora=64),
    tie_embeddings=False,
    subquadratic=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, act="relu",
        norm="layer", rope="none",
        rwkv=RWKVConfig(head_dim=16, decay_lora=16, tokenshift_lora=8),
        tie_embeddings=False, subquadratic=True,
    )
