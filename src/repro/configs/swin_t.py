"""Swin-T — the paper's own target model (plus ViT-B for reference).

Used by the faithful-reproduction path: the ASIC cycle model walks these
layers to reproduce Fig. 2 (FLOPs/param distribution), Table III (403.2
GOPS peak) and Table IV (22.4 ms / 44.5 img/s on Swin-T), and the vision
examples run a scaled-down Swin on synthetic images through the row-wise
kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class SwinConfig:
    name: str = "swin-t"
    img_size: int = 224
    patch: int = 4                     # 4x4 stride-4 patch-embed conv
    in_chans: int = 3
    embed_dim: int = 96                # doubles per stage
    depths: Tuple[int, ...] = (2, 2, 6, 2)
    num_heads: Tuple[int, ...] = (3, 6, 12, 24)
    window: int = 7
    mlp_ratio: float = 4.0
    num_classes: int = 1000
    qkv_bias: bool = True


CONFIG = SwinConfig()


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str = "vit-b16"
    img_size: int = 224
    patch: int = 16
    in_chans: int = 3
    embed_dim: int = 768
    depth: int = 12
    num_heads: int = 12
    mlp_ratio: float = 4.0
    num_classes: int = 1000


VIT_CONFIG = ViTConfig()


def reduced() -> SwinConfig:
    return SwinConfig(name="swin-smoke", img_size=56, patch=4, embed_dim=32,
                      depths=(1, 1), num_heads=(2, 4), window=7,
                      num_classes=10)
