"""qwen2-vl-2b [arXiv:2409.12191] — M-RoPE, dynamic-resolution VLM.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. The vision
frontend is a stub per the brief: input_specs() provides precomputed
patch embeddings; the backbone applies M-RoPE over (t, h, w) sections.
"""
from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    act="silu",
    norm="rms",
    rope="mrope",
    frontend="vision",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, act="silu", norm="rms",
        rope="mrope", frontend="vision",
    )
