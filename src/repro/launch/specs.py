"""Abstract input/state specs for every (arch x shape) cell.

Everything here is ShapeDtypeStruct-based: weak-type-correct, shardable,
zero allocation — the dry-run lowers against these stand-ins.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig, ShapeSpec
from repro.models import lm
from repro.train import step as train_step_lib


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract model inputs for one cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": sds((b, s), jnp.int32)}
        if shape.kind == "train":
            specs["labels"] = sds((b, s), jnp.int32)
        if cfg.frontend == "audio":
            specs["frames"] = sds((b, cfg.cross_len, cfg.d_model),
                                  jnp.bfloat16)
        if cfg.frontend == "vision":
            n_patches = min(1024, s // 4)
            specs["vis_embeds"] = sds((b, n_patches, cfg.d_model),
                                      jnp.bfloat16)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"tokens": sds((b, 1), jnp.int32),
            "lengths": sds((b,), jnp.int32)}


def abstract_init(cfg: ModelConfig) -> Tuple:
    """(params_struct, logical_specs): structure without allocation.

    The logical-spec tree contains static strings, so we obtain it by
    tracing init once with eval_shape (params become structs; the spec
    tree is built from python values and survives as-is).
    """
    box = {}

    def go(k):
        params, spec_tree = lm.init_lm(k, cfg)
        box["specs"] = spec_tree       # static python data, via closure
        return params

    params = jax.eval_shape(go, jax.random.PRNGKey(0))
    return params, box["specs"]


def abstract_train_state(cfg: ModelConfig, tcfg) -> Tuple:
    """(TrainState structs, TrainState logical specs)."""
    params, pspecs = abstract_init(cfg)
    state = jax.eval_shape(
        lambda p: train_step_lib.init_state(p, tcfg), params)
    specs = train_step_lib.state_logical_specs(pspecs, tcfg)
    return state, specs


def abstract_cache(cfg: ModelConfig, batch: int, alloc: int):
    cache = jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, alloc, jnp.bfloat16))
    specs = lm.cache_logical_specs(cache)
    return cache, specs


def param_count(cfg: ModelConfig) -> dict:
    """Exact N (and active-N for MoE) from the abstract param tree."""
    params, _ = abstract_init(cfg)
    total = sum(int(x.size) for x in jax.tree.leaves(params))
    # report true params (exclude vocab- and expert-padding)
    pad = (lm.padded_vocab(cfg) - cfg.vocab) * cfg.d_model
    total -= pad * (1 if cfg.tie_embeddings else 2)
    if cfg.moe is not None:
        from repro.models.moe import padded_experts
        e_pad = padded_experts(cfg) - cfg.moe.n_experts
        per_expert = 3 * cfg.d_model * cfg.moe.d_ff
        total -= (e_pad * per_expert + e_pad * cfg.d_model) * cfg.n_layers
    active = total
    if cfg.moe is not None:
        mo = cfg.moe
        # routed expert leaves: wi/wg/wo carry the n_experts dim
        inactive_frac = (mo.n_experts - mo.top_k) / mo.n_experts
        expert_params = 0
        for stage_p in params["stages"]:
            for blk in stage_p["stacked"].values():
                ffn = blk.get("ffn", {})
                for name in ("wi", "wg", "wo"):
                    if name in ffn:
                        expert_params += int(ffn[name].size)
        active = total - int(expert_params * inactive_frac)
    return {"total": total, "active": active}
