"""HLO-walking cost analyzer with while-loop trip-count scaling.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body* once,
which silently undercounts every scan-stacked model by its layer count.
This module re-derives the three roofline inputs directly from the
optimized per-device HLO text:

  * FLOPs       — every ``dot``/``convolution`` (2 x numel(output) x
                  contraction size), scaled by enclosing while trips;
  * HBM bytes   — per top-level instruction: operand bytes + result
                  bytes (post-fusion, so fusion internals don't count —
                  this is the HBM-traffic model);
  * collective bytes — output bytes of all-gather / all-reduce /
                  reduce-scatter / all-to-all / collective-permute.

Trip counts come from the ``backend_config known_trip_count`` that XLA
attaches to scan-derived whiles (fallback: the literal in the paired
condition computation).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id",
             "bitcast-convert", "add-dependency", "domain"}


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    total_b = total_e = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_e, total_b


def _dims_of(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str          # everything after '<op>('


@dataclasses.dataclass
class Block:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]


def _split_shape_op(rest: str) -> Optional[Tuple[str, str, str]]:
    """'<shape> <op>(<args...>' -> (shape, op, args)."""
    rest = rest.strip()
    if rest.startswith("("):                      # tuple shape
        depth = 0
        end = -1
        for i, c in enumerate(rest):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape, tail = rest[:end + 1], rest[end + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, tail = rest[:sp], rest[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\(", tail)
    if not m:
        return None
    return shape, m.group(1), tail[m.end():]


_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")


def parse_blocks(hlo: str) -> Tuple[Dict[str, Block], Optional[str]]:
    blocks: Dict[str, Block] = {}
    entry_name = None
    cur: Optional[Block] = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and "{" in line:
            m = _HEADER_RE.match(line.strip())
            if m:
                cur = Block(name=m.group(1), instrs=[], shapes={})
                blocks[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    entry_name = cur.name
                continue
        if cur is None:
            continue
        ls = line.strip()
        if ls.startswith("}"):
            cur = None
            continue
        if ls.startswith("ROOT "):
            ls = ls[5:]
        m = re.match(r"^%?([\w.\-]+)\s*=\s*(.*)$", ls)
        if not m:
            continue
        parsed = _split_shape_op(m.group(2))
        if not parsed:
            continue
        shape, op, args = parsed
        instr = Instr(name=m.group(1), shape=shape, op=op, rest=args)
        cur.instrs.append(instr)
        cur.shapes[instr.name] = shape
    return blocks, entry_name


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _trip_count(ins: Instr, blocks: Dict[str, Block]) -> int:
    m = _TRIP_RE.search(ins.rest)
    if m:
        return int(m.group(1))
    mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
    if mc and mc.group(1) in blocks:
        best = 1
        for ci in blocks[mc.group(1)].instrs:
            if ci.op == "constant" and ci.shape in ("s32[]", "u32[]",
                                                    "s64[]"):
                mm = re.search(r"^\((\d+)\)", ci.rest)
                if mm:
                    best = max(best, int(mm.group(1)))
        return best
    return 1


def _contraction_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out_e, _ = _shape_elems_bytes(ins.shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    args = ins.rest.split("),")[0]
    ops = _OPERAND_RE.findall(args)
    if not m or not ops:
        return 2.0 * out_e
    dims = _dims_of(shapes.get(ops[0], ""))
    k = 1
    for idx in filter(None, m.group(1).split(",")):
        i = int(idx)
        if i < len(dims):
            k *= dims[i]
    return 2.0 * out_e * k


ATTN_TAGS = ("chunked_attention", "_sdpa", "attention_ref",
             "_chunked_fwd", "_flash_bwd")
_SFID_RE = re.compile(r"stack_frame_id=(\d+)")
ATTN_CHUNK = 1024          # models/attention.py chunk size


def _is_score_shape(shape_str: str) -> bool:
    # (.., Sq, chunk) probability/score tensors, incl. rank-3 reshapes;
    # no model dim in the assigned pool equals the kv-chunk size, so the
    # trailing-dim test is unambiguous.
    dims = _dims_of(shape_str)
    return len(dims) >= 3 and dims[-1] == ATTN_CHUNK


def parse_attn_frames(hlo: str) -> set:
    """Frame ids whose Python call chain passes through the attention
    softmax path (resolved via the FileNames/FunctionNames/FileLocations/
    StackFrames tables XLA emits at the top of the module text)."""
    sections = {"FunctionNames": {}, "FileLocations": {}, "StackFrames": {}}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if s in sections:
            cur = s
            continue
        if cur is None:
            continue
        m = re.match(r"^(\d+)\s+(.*)$", s)
        if not m:
            if s and not s[0].isdigit():
                cur = None
            continue
        idx, rest = int(m.group(1)), m.group(2)
        if cur == "FunctionNames":
            sections[cur][idx] = rest.strip('"')
        elif cur == "FileLocations":
            mm = re.search(r"function_name_id=(\d+)", rest)
            sections[cur][idx] = int(mm.group(1)) if mm else 0
        elif cur == "StackFrames":
            mm = re.search(r"file_location_id=(\d+)\s+parent_frame_id=(\d+)",
                           rest)
            if mm:
                sections[cur][idx] = (int(mm.group(1)), int(mm.group(2)))
    fnames, flocs, frames = (sections["FunctionNames"],
                             sections["FileLocations"],
                             sections["StackFrames"])
    attn_fn_ids = {i for i, n in fnames.items()
                   if any(t in n for t in ATTN_TAGS)}
    out = set()
    for fid in frames:
        cur_id, seen = fid, set()
        while cur_id in frames and cur_id not in seen:
            seen.add(cur_id)
            loc, parent = frames[cur_id]
            if flocs.get(loc) in attn_fn_ids:
                out.add(fid)
                break
            if parent == cur_id:
                break
            cur_id = parent
    return out


_PASSTHROUGH = {"convert", "bitcast", "copy", "reshape", "transpose"}


def _fusion_alias_info(fb: Block):
    """For a fusion computation: which parameter indices are only
    dynamically sliced (read a slice, not the buffer) or are DUS targets
    (aliased in-place update). Unary passthrough chains (convert /
    bitcast / copy — XLA:CPU's bf16 emulation inserts f32 round-trips
    that a TPU would not materialize) are collapsed before the check.
    -> (sliced {idx: slice_bytes}, dus {idx: update_bytes})."""
    param_idx = {}
    consumers = {}
    for fins in fb.instrs:
        if fins.op == "parameter":
            mm = re.match(r"^(\d+)\)", fins.rest)
            if mm:
                param_idx[fins.name] = int(mm.group(1))
        for on in _OPERAND_RE.findall(fins.rest.split("), ")[0]):
            consumers.setdefault(on, []).append(fins)

    def terminal_consumers(name, depth=0):
        """Collapse unary passthrough chains to the effective consumers."""
        out = []
        for c in consumers.get(name, []):
            if c.op in _PASSTHROUGH and depth < 6:
                nxt = terminal_consumers(c.name, depth + 1)
                out.extend(nxt if nxt else [c])
            else:
                out.append(c)
        return out

    def first_operand_chain(ins):
        """Does operand 0 of `ins` trace back (through passthroughs) to a
        parameter? Returns that parameter name or None."""
        cur = _OPERAND_RE.findall(ins.rest.split("), ")[0])
        cur = cur[0] if cur else None
        for _ in range(8):
            if cur is None:
                return None
            if cur in param_idx:
                return cur
            producer = next((fi for fi in fb.instrs if fi.name == cur),
                            None)
            if producer is None or producer.op not in _PASSTHROUGH:
                return None
            nxt = _OPERAND_RE.findall(producer.rest.split("), ")[0])
            cur = nxt[0] if nxt else None
        return None

    sliced, dus = {}, {}
    for pname, idx in param_idx.items():
        cons = terminal_consumers(pname)
        if not cons:
            continue
        if all(c.op == "dynamic-slice"
               and first_operand_chain(c) == pname for c in cons):
            _, sb = _shape_elems_bytes(cons[0].shape)
            sliced[idx] = sb * len(cons)
        elif (len(cons) == 1 and cons[0].op == "dynamic-update-slice"
              and first_operand_chain(cons[0]) == pname):
            ops_in = _OPERAND_RE.findall(cons[0].rest.split("), ")[0])
            upd_b = 0
            if len(ops_in) > 1 and ops_in[1] in fb.shapes:
                _, upd_b = _shape_elems_bytes(fb.shapes[ops_in[1]])
            if upd_b == 0:
                _, full = _shape_elems_bytes(cons[0].shape)
                upd_b = full // 8          # conservative guess
            dus[idx] = upd_b
    return sliced, dus


def instr_traffic(ins: Instr, block: Block,
                  blocks: Optional[Dict[str, Block]] = None):
    """HBM traffic for one leaf instruction -> (bytes, out_b, op_b).

    Aliasing-aware: dynamic-update-slice / scatter update their largest
    operand in place (charge the written slice, not the buffer);
    dynamic-slice reads only the slice. Fusions are inspected for
    internal slices/updates of their parameters.
    """
    _, out_raw = _shape_elems_bytes(ins.shape)
    args = ins.rest.split("), ")[0]
    onames = _OPERAND_RE.findall(args)
    operand_bytes = []
    for oname in onames:
        if oname in block.shapes:
            _, b = _shape_elems_bytes(block.shapes[oname])
            operand_bytes.append(b)
        else:
            operand_bytes.append(0)
    op_b = sum(operand_bytes)

    if ins.op in ("dynamic-update-slice", "scatter") and operand_bytes:
        alias = max(operand_bytes)
        slice_b = max(op_b - alias, min(operand_bytes) if operand_bytes
                      else 0)
        return 2 * slice_b, slice_b, slice_b
    if ins.op == "dynamic-slice" and operand_bytes:
        rest_ops = op_b - max(operand_bytes)
        return 2 * out_raw + rest_ops, out_raw, out_raw + rest_ops

    if ins.op == "fusion" and blocks is not None:
        fm = re.search(r"calls=%?([\w.\-]+)", ins.rest)
        fb = blocks.get(fm.group(1)) if fm else None
        if fb is not None:
            sliced, dus = _fusion_alias_info(fb)
            if sliced or dus:
                op_adj = 0
                for idx, b in enumerate(operand_bytes):
                    if idx in sliced:
                        op_adj += sliced[idx]
                    elif idx in dus:
                        op_adj += dus[idx]       # read the update source
                    else:
                        op_adj += b
                # aliased DUS buffers appear in the output too: subtract
                # the buffer, add the written slice
                out_adj = out_raw
                for idx, upd in dus.items():
                    if idx < len(operand_bytes):
                        out_adj = max(out_adj - operand_bytes[idx] + upd,
                                      0)
                return out_adj + op_adj, out_adj, op_adj

    return out_raw + op_b, out_raw, op_b


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLL_KINDS})
    # HBM traffic internal to the attention softmax path (score matrices,
    # masks, running stats). The row-wise flash kernel keeps all of this
    # in VMEM: `bytes - attn_internal_bytes` is the fused-kernel memory
    # traffic (reported as the kernel-adjusted roofline term).
    attn_internal_bytes: float = 0.0

    def scaled(self, k: float) -> "HLOCost":
        return HLOCost(self.flops * k, self.bytes * k,
                       {n: v * k for n, v in self.coll_bytes.items()},
                       self.attn_internal_bytes * k)

    def add(self, o: "HLOCost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for n, v in o.coll_bytes.items():
            self.coll_bytes[n] = self.coll_bytes.get(n, 0.0) + v
        self.attn_internal_bytes += o.attn_internal_bytes


def _block_cost(block: Block, blocks: Dict[str, Block],
                memo: Dict[str, HLOCost],
                attn_frames: Optional[set] = None) -> HLOCost:
    attn_frames = attn_frames if attn_frames is not None else set()
    if block.name in memo:
        return memo[block.name]
    memo[block.name] = HLOCost()        # cycle guard
    total = HLOCost()
    for ins in block.instrs:
        if ins.op in _FREE_OPS:
            continue
        if ins.op == "while":
            mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
            trips = _trip_count(ins, blocks)
            if mb and mb.group(1) in blocks:
                total.add(_block_cost(blocks[mb.group(1)], blocks,
                                      memo, attn_frames).scaled(trips))
            continue
        if ins.op in ("conditional", "call"):
            for key in ("branch_computations", "to_apply",
                        "true_computation", "false_computation"):
                mm = re.search(key + r"=\{?%?([\w.\-]+)", ins.rest)
                if mm and mm.group(1) in blocks:
                    total.add(_block_cost(blocks[mm.group(1)], blocks,
                                          memo, attn_frames))
            continue
        # leaf op: HBM traffic = operand bytes + result bytes
        byt, out_b, op_b = instr_traffic(ins, block, blocks)
        total.bytes += byt
        tagged = "rowwise_attn" in ins.rest
        if not tagged:
            sf = _SFID_RE.search(ins.rest)
            tagged = bool(sf and int(sf.group(1)) in attn_frames)
        if tagged:
            # ALL traffic inside the attention scope is kernel-internal;
            # the roofline adds back the analytic flash-kernel minimum
            # (q/k/v reads + out write) — see roofline.flash_min_bytes.
            total.attn_internal_bytes += byt
        if ins.op in ("dot", "convolution"):
            total.flops += _contraction_flops(ins, block.shapes)
        elif ins.op == "fusion":
            fm = re.search(r"calls=%?([\w.\-]+)", ins.rest)
            if fm and fm.group(1) in blocks:
                fb = blocks[fm.group(1)]
                for fins in fb.instrs:
                    if fins.op in ("dot", "convolution"):
                        total.flops += _contraction_flops(fins, fb.shapes)
        base = next((k for k in _COLL_KINDS
                     if ins.op == k or ins.op.startswith(k)), None)
        if base and not ins.op.endswith("-done"):
            total.coll_bytes[base] += out_b
    memo[block.name] = total
    return total


def analyze_hlo(hlo: str) -> HLOCost:
    blocks, entry_name = parse_blocks(hlo)
    entry = blocks.get(entry_name) if entry_name else None
    if entry is None:
        entry = max(blocks.values(), key=lambda b: len(b.instrs))
    return _block_cost(entry, blocks, {}, parse_attn_frames(hlo))
