"""Roofline terms from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / peak_FLOP/s        (per chip)
  memory term     = HLO_bytes / HBM_bw             (per chip)
  collective term = collective_bytes / link_bw     (per chip)

``compiled.cost_analysis()`` reports the *per-device* (post-SPMD) module,
so the per-chip forms above match the brief's global/chips formulation.
collective_bytes is not in cost_analysis: we parse the HLO text and sum
wire bytes per collective kind (all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute).

Hardware constants (TPU v5e-class, from the brief): 197 TFLOP/s bf16 per
chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]{1,0}' -> bytes. Tuples handled by the caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device wire bytes by collective kind.

    Convention: we count the *output* bytes of each collective op on the
    per-device module — for all-gather that is the gathered (full) tile a
    device must receive; for reduce-scatter the reduced shard it
    receives; for all-reduce the full buffer (ring: ~2x, we count 1x —
    consistent lower bound); for all-to-all / collective-permute the
    transferred buffer.
    """
    out: Dict[str, int] = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match '<shape> <name> = <op>(' where op is a collective;
        # fusion-wrapped collectives keep their op name in HLO.
        m = re.match(r"(?:ROOT )?[%\w.\-]+ = (\(?[\w\[\],{}\s/]*\)?) "
                     r"([\w\-]+)(?:-start|-done)?\(", ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = None
        for k in _COLL_KINDS:
            if op == k or op.startswith(k):
                base = k
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        out[base] += _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: Dict[str, int]
    model_flops: float                 # 6*N*D (train) / 2*N*D (serve)
    attn_internal_bytes: float = 0.0   # softmax-scope HBM traffic (see
                                       # hlo_cost: flash kernel removes it)
    flash_min_bytes: float = 0.0       # analytic kernel HBM floor
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_t(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def memory_t(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def memory_t_fused(self) -> float:
        """Memory term with the row-wise flash attention kernel: all
        softmax-scope traffic replaced by the kernel's analytic HBM
        minimum (q/k/v reads, out write, recompute re-reads)."""
        return max(self.bytes_per_device - self.attn_internal_bytes
                   + self.flash_min_bytes, 0.0) / self.hbm_bw

    @property
    def collective_t(self) -> float:
        return sum(self.coll_bytes_per_device.values()) / self.ici_bw

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_t, "memory": self.memory_t_fused,
                 "collective": self.collective_t}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three overlapped engines
        (memory term is the fused-kernel one — the deployed config)."""
        return max(self.compute_t, self.memory_t_fused, self.collective_t)

    @property
    def step_time_unfused(self) -> float:
        """Paper-faithful baseline: attention scores round-trip HBM
        between the two row-wise matmuls (the ASIC's separate
        post-processing pass)."""
        return max(self.compute_t, self.memory_t, self.collective_t)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips * HLO_FLOPs): remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """model FLOPs / (chips * peak * step_time)."""
        denom = self.chips * self.peak_flops * self.step_time
        return self.model_flops / denom if denom else 0.0

    @property
    def mfu_unfused(self) -> float:
        denom = self.chips * self.peak_flops * self.step_time_unfused
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "model_flops": self.model_flops,
            "attn_internal_bytes": self.attn_internal_bytes,
            "flash_min_bytes": self.flash_min_bytes,
            "compute_t": self.compute_t, "memory_t": self.memory_t,
            "memory_t_fused": self.memory_t_fused,
            "collective_t": self.collective_t, "bound": self.bound,
            "step_time": self.step_time,
            "step_time_unfused": self.step_time_unfused,
            "mfu": self.mfu, "mfu_unfused": self.mfu_unfused,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6*N*D for training, 2*N*D for forward-only serving steps."""
    mult = 6 if kind == "train" else 2
    return float(mult) * n_params_active * tokens


def flash_min_bytes(cfg, shape, chips: int) -> float:
    """Analytic per-device HBM floor of the row-wise flash attention
    kernel, per step: read q/k/v, write out (+lse), with the backward
    re-reading q/k/v/out/do and writing dq/dk/dv (recompute-from-lse).

    train:   ~3.5x the forward traffic (fwd + recompute + grads)
    prefill: forward only
    decode:  one cache read + O(1)-token q/out
    """
    hd = cfg.head_dim
    total = 0.0
    for stage in cfg.stages():
        for blk in stage.body:
            if blk.mixer != "attn":
                continue
            n_layers = stage.repeat
            if shape.kind == "decode":
                kv_len = min(blk.window, shape.seq_len) if blk.window \
                    else shape.seq_len
                kv_b = (shape.global_batch * kv_len * cfg.n_kv_heads
                        * hd * 2 * 2)
                q_b = shape.global_batch * cfg.n_heads * hd * 2 * 4
                total += n_layers * (kv_b + q_b)
            else:
                t = shape.global_batch * shape.seq_len
                q_b = t * cfg.n_heads * hd * 2 * 2     # read q, write o
                kv_b = t * cfg.n_kv_heads * hd * 2 * 2
                per = q_b + kv_b
                total += n_layers * (3.5 * per if shape.kind == "train"
                                     else per)
    return total / chips


def analyze(compiled, hlo_text: str, *, arch: str, shape: str,
            mesh_name: str, chips: int, n_active: int, tokens: int,
            kind: str, flash_min: float = 0.0) -> RooflineReport:
    """Roofline terms from the compiled per-device module.

    Uses the while-trip-scaled HLO walk (launch/hlo_cost.py) because
    XLA's cost_analysis counts scan bodies once; the raw cost_analysis
    numbers are preserved in the artifact for reference.
    """
    from repro.launch import hlo_cost
    cost = hlo_cost.analyze_hlo(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=cost.flops, bytes_per_device=cost.bytes,
        coll_bytes_per_device={k: int(v)
                               for k, v in cost.coll_bytes.items()},
        model_flops=model_flops(n_active, tokens, kind),
        attn_internal_bytes=cost.attn_internal_bytes,
        flash_min_bytes=flash_min)


def raw_cost_analysis(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
