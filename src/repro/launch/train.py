"""Training driver: data pipeline -> sharded train step -> checkpoints.

Production behaviors: exact resume (checkpoint step == data step), async
checkpointing, SIGTERM preemption hook (final sync save), NaN-step
skipping, optional cross-pod int8 gradient compression, host-device mesh
for local runs.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
      --smoke --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpointer as ckpt
from repro.configs import get_config, get_reduced
from repro.core import partitioning
from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticLM
from repro.models import lm
from repro.optim import adamw
from repro.train import step as tsl


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-pods", action="store_true")
    ap.add_argument("--mesh", default="none",
                    help="none | host (2,2,2 host devices)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.smoke else get_config(args.arch)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model}")

    tcfg = tsl.TrainConfig(
        opt=adamw.AdamWConfig(lr=args.lr),
        warmup_steps=max(args.steps // 20, 2), total_steps=args.steps,
        microbatches=args.microbatches,
        compress_pods=args.compress_pods)

    mesh = None
    if args.mesh == "host":
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()

    key = jax.random.PRNGKey(args.seed)
    params, pspecs = lm.init_lm(key, cfg, dtype=jnp.float32)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")
    state = tsl.init_state(params, tcfg)

    start_step = 0
    saver = None
    if args.ckpt_dir:
        saver = ckpt.AsyncCheckpointer(args.ckpt_dir)
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state, extra = ckpt.restore(args.ckpt_dir, latest, state)
            start_step = extra["data_step"]
            print(f"resumed from step {start_step}")

    ds = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                global_batch=args.batch, seed=args.seed))
    it = PrefetchIterator(ds.iter_from(start_step))

    # preemption hook: a final synchronous checkpoint on SIGTERM
    preempted = {"flag": False}

    def on_sigterm(signum, frame):
        preempted["flag"] = True

    signal.signal(signal.SIGTERM, on_sigterm)

    # straggler mitigation: EMA of step latency; steps slower than
    # STRAGGLER_X times the EMA are logged (on a multi-host deployment
    # this signal feeds the controller that drains/replaces the slow
    # host and triggers an elastic restore onto the shrunk mesh —
    # checkpointing + reshard-on-load already support that path).
    STRAGGLER_X = 3.0
    ema = {"dt": None, "flagged": 0}

    def track_step_time(dt):
        if ema["dt"] is None:
            ema["dt"] = dt
            return False
        slow = dt > STRAGGLER_X * ema["dt"]
        ema["dt"] = 0.9 * ema["dt"] + 0.1 * dt
        if slow:
            ema["flagged"] += 1
            print(f"[straggler] step took {dt*1e3:.0f}ms "
                  f"(EMA {ema['dt']*1e3:.0f}ms) — flagged "
                  f"{ema['flagged']} total")
        return slow

    step_fn = tsl.make_train_step(cfg, tcfg, mesh=mesh)
    ctx = partitioning.use_mesh(mesh) if mesh is not None else None
    if ctx:
        ctx.__enter__()
    try:
        jstep = jax.jit(step_fn)
        t0 = time.time()
        for i in range(start_step, args.steps):
            batch = jax.tree.map(jnp.asarray, next(it))
            t_step = time.time()
            state, metrics = jstep(state, batch)
            jax.block_until_ready(metrics["loss"])
            track_step_time(time.time() - t_step)
            if i % 10 == 0 or i == args.steps - 1:
                dt = time.time() - t0
                tok_s = (i - start_step + 1) * args.batch * args.seq / dt
                print(f"step {i}: loss={float(metrics['loss']):.4f} "
                      f"acc={float(metrics['accuracy']):.3f} "
                      f"gnorm={float(metrics['grad_norm']):.2f} "
                      f"skip={int(metrics.get('skipped', 0))} "
                      f"tok/s={tok_s:.0f}")
            if saver and ((i + 1) % args.ckpt_every == 0):
                saver.save_async(i + 1, state, extra={"data_step": i + 1})
            if preempted["flag"]:
                print("SIGTERM: sync checkpoint + exit")
                if saver:
                    saver.wait()
                    ckpt.save(args.ckpt_dir, i + 1, state,
                              extra={"data_step": i + 1})
                sys.exit(0)
        if saver:
            saver.wait()
            ckpt.save(args.ckpt_dir, args.steps, state,
                      extra={"data_step": args.steps})
    finally:
        it.close()
        if ctx:
            ctx.__exit__(None, None, None)
    print("done")


if __name__ == "__main__":
    main()
