"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is the
slow (DCN/cross-pod) link — gradient compression targets it.

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh over host devices for distribution tests."""
    return jax.make_mesh(shape, axes)


def describe(mesh) -> str:
    return (f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} = "
            f"{mesh.devices.size} devices on "
            f"{mesh.devices.flat[0].platform}")
