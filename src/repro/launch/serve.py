"""Serving driver: paged-KV continuous-batching engine over synthetic
requests.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
      --requests 8 --slots 4 --page-size 16

Tensor-parallel serving (``--mesh-shape model=4``) needs the devices to
exist before jax initialises; on a CPU box export
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` first.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.core.block_traffic import serve_kv_traffic
from repro.core.types import PagingConfig
from repro.models import lm
from repro.serve import faults as faults_mod
from repro.serve import placement as placement_mod
from repro.serve.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=0,
                    help="real pages per layer pool (0 = full occupancy; "
                         "smaller oversubscribes and defers admissions)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill panel size (a bucket-ladder "
                         "power of two; 0 = monolithic bucketed prefill). "
                         "Prompts longer than this split across engine "
                         "steps interleaved with decode, removing the "
                         "TTFT cliff the largest bucket causes")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix cache over token prefixes: "
                         "admission maps fully shared prompt pages into "
                         "the new slot's block table and chunked "
                         "prefill replays only the uncached suffix "
                         "(requires --prefill-chunk; sliding-window "
                         "archs silently opt out)")
    ap.add_argument("--prefill-token-budget", type=int, default=0,
                    help="Sarathi-style cap on prefill tokens advanced "
                         "per engine step across mid-prefill slots "
                         "(0 = unbounded; the oldest slot always "
                         "advances)")
    ap.add_argument("--prompt-len", type=int, default=4,
                    help="base synthetic prompt length (request i gets "
                         "prompt_len + i %% 8 tokens); raise above "
                         "--prefill-chunk to drive chunked admissions")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="sample from the k highest-logit tokens "
                         "(0 = full vocab). Static per engine — one "
                         "compiled program; greedy rows (t=0) stay "
                         "bit-identical regardless")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass cutoff (1.0 = off); "
                         "static per engine, like --top-k")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="self-speculative decoding: a host-side "
                         "prompt-lookup drafter proposes up to K "
                         "tokens/step, one batched verify forward "
                         "scores them, rejected tails roll back "
                         "page-exactly. Greedy streams stay "
                         "bit-identical to K=0; repetitive prompts "
                         "accept >1 token/step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-plan", default="",
                    help="deterministic chaos schedule, e.g. "
                         "'alloc@3,nan@5.1,exc@7,slow@2:0.01' "
                         "(kind@clock[.slot][:arg]); or 'random:SEED' "
                         "for a seeded random plan. The engine recovers "
                         "and every request still reaches a terminal "
                         "completion — this flag exists to demo that")
    ap.add_argument("--preempt-patience", type=int, default=None,
                    help="preempt the youngest slot after this many "
                         "consecutive iterations with the queue head "
                         "blocked on pages (default: off; deadline-"
                         "priority preemption is always on)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds (requests "
                         "past it retire with status 'deadline')")
    ap.add_argument("--mesh-shape", default="",
                    help="tensor-parallel mesh, e.g. 'model=4' or '4' "
                         "('' or '1' = single device). Head counts, "
                         "d_ff and the padded vocab must divide by the "
                         "mesh size; indivisible shapes are rejected at "
                         "engine construction, not mid-step")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.smoke else get_config(args.arch)
    placement = placement_mod.from_mesh_shape(args.mesh_shape)
    if args.fault_plan.startswith("random:"):
        plan = faults_mod.FaultPlan.random(
            int(args.fault_plan.split(":", 1)[1]), n_steps=64,
            n_slots=args.slots, p_alloc=0.1, p_nan=0.05, p_exc=0.02)
    else:
        plan = faults_mod.parse_plan(args.fault_plan)
    key = jax.random.PRNGKey(args.seed)
    params, _ = lm.init_lm(key, cfg, dtype=jnp.float32)
    eng = Engine(params, cfg, n_slots=args.slots, max_len=args.max_len,
                 eos_id=-1, temperature=args.temperature,
                 top_k=args.top_k, top_p=args.top_p, seed=args.seed,
                 paging=PagingConfig(
                     page_size=args.page_size, n_pages=args.n_pages,
                     prefill_chunk=args.prefill_chunk,
                     prefix_cache=args.prefix_cache,
                     prefill_token_budget=args.prefill_token_budget,
                     speculate_k=args.speculate),
                 placement=placement, faults=plan,
                 preempt_patience=args.preempt_patience)
    for i in range(args.requests):
        plen = min(args.prompt_len + (i % 8), args.max_len)
        prompt = jax.random.randint(jax.random.fold_in(key, i),
                                    (plen,), 0, cfg.vocab)
        eng.submit(Request(rid=i, prompt=prompt, max_new=args.max_new,
                           deadline_s=args.deadline))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(c.tokens) for c in done)
    print(f"arch={cfg.name} slots={args.slots} requests={len(done)} "
          f"page_size={eng.page_size} pool={eng.pool.n_pages} pages "
          f"placement={placement.describe()}")
    for c in sorted(done, key=lambda c: c.rid)[:4]:
        print(f"  rid={c.rid} status={c.status} prompt_len={c.prompt_len} "
              f"tokens={c.tokens[:8]}... latency={c.latency_s*1e3:.0f}ms "
              f"ttft={c.ttft_s*1e3:.0f}ms")
    by_status: dict = {}
    for c in done:
        by_status[c.status] = by_status.get(c.status, 0) + 1
    print(f"decoded {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s with continuous batching)")
    print(f"statuses: {by_status}  faults: {plan.describe()}  "
          f"stats: {eng.stats}")
    traffic = serve_kv_traffic(eng.kv_trace, cfg, n_slots=args.slots,
                               max_len=args.max_len,
                               page_size=eng.page_size)
    compiles = eng.compile_counts()
    if traffic["dense_bytes"]:
        kv = (f"KV bytes/trace: paged={traffic['paged_bytes']:,} "
              f"dense={traffic['dense_bytes']:,} "
              f"(x{traffic['ratio']:.2f} less)")
    else:
        kv = "KV traffic: n/a (no attention layers)"
    print(f"{kv}; compiles: prefill={compiles['prefill']} "
          f"chunk={compiles['chunk']} step={compiles['step']} "
          f"spec={compiles.get('spec', 0)} "
          f"buckets={eng.buckets} prefill_chunk={eng.prefill_chunk}")


if __name__ == "__main__":
    main()
