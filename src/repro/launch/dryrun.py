"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 host placeholder devices, lowers the real
train/prefill/serve step against ShapeDtypeStruct stand-ins, compiles,
and records memory analysis + cost analysis + the collective schedule
for the roofline table.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
  python -m repro.launch.dryrun --arch gemma3-27b --shape long_500k \
      --rules kv_seq=model,kv_heads=data
"""
import os

# must land before the jax import below initialises the backend
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cell_applicable, get_config
from repro.core import partitioning
from repro.core.types import ModelConfig, ShapeSpec
from repro.launch import roofline, specs
from repro.launch.mesh import describe, make_production_mesh
from repro.models import lm
from repro.train import step as train_step_lib


def cell_rules(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Per-cell logical-rule overrides (the baseline schedule)."""
    rules = {}
    if shape.kind in ("decode", "prefill"):
        rules["kv_seq"] = "model"       # shard the cache along sequence
    if shape.kind == "decode":
        # serving keeps weights resident in their shards (Megatron-TP
        # layout, no FSDP dim): re-gathering weights to multiply a
        # handful of decode tokens is pure waste (§Perf granite iter 2/4)
        rules["embed"] = None
        rules["qkv"] = "model"
        rules["ffn"] = "model"
        rules["decode_attn"] = "sharded"   # seq-sharded flash decode
        if shape.global_batch == 1:
            rules["batch"] = None       # batch=1 cannot shard
            rules["kv_heads"] = "data"  # use the idle data axis on heads
    return rules


def _sharding_trees(mesh, cfg, shape, tcfg):
    """(abstract args, in_shardings, out_shardings, fn) per cell kind."""
    params_s, pspecs = specs.abstract_init(cfg)
    inputs = specs.input_specs(cfg, shape)

    def shard_of(names, shape=None):
        return partitioning.named_sharding(mesh, *names, shape=shape)

    batch_sh = {k: shard_of(("batch",) + (None,) * (v.ndim - 1), v.shape)
                for k, v in inputs.items()}

    if shape.kind == "train":
        state_s, state_specs_tree = specs.abstract_train_state(cfg, tcfg)
        state_sh = partitioning.tree_shardings(mesh, state_specs_tree,
                                              like=state_s)

        def fn(state, batch):
            step = train_step_lib.make_train_step(cfg, tcfg,
                                                  param_specs=pspecs)
            return step(state, batch)

        args = (state_s, inputs)
        in_sh = (state_sh, batch_sh)
        out_sh = (state_sh, None)
        donate = (0,)
    elif shape.kind == "prefill":
        cache_s, cache_specs_tree = specs.abstract_cache(
            cfg, shape.global_batch, shape.seq_len)
        cache_sh = partitioning.tree_shardings(mesh, cache_specs_tree,
                                               like=cache_s)
        param_sh = partitioning.tree_shardings(mesh, pspecs, like=params_s)

        def fn(params, batch):
            extra = {k: v for k, v in batch.items() if k != "tokens"}
            return lm.prefill(params, batch["tokens"], cfg,
                              extra=extra or None)

        args = (params_s, inputs)
        in_sh = (param_sh, batch_sh)
        out_sh = (shard_of(("batch", "vocab_act"),
                           (shape.global_batch, 1)), cache_sh)
        donate = ()
    else:  # decode
        cache_s, cache_specs_tree = specs.abstract_cache(
            cfg, shape.global_batch, shape.seq_len)
        cache_sh = partitioning.tree_shardings(mesh, cache_specs_tree,
                                               like=cache_s)
        param_sh = partitioning.tree_shardings(mesh, pspecs, like=params_s)

        def fn(params, cache, batch):
            logits, new_cache = lm.decode_step(
                params, cache, batch["tokens"], batch["lengths"], cfg)
            return jnp.argmax(logits, -1).astype(jnp.int32), new_cache

        args = (params_s, cache_s, inputs)
        in_sh = (param_sh, cache_sh, batch_sh)
        out_sh = (shard_of(("batch",), (shape.global_batch,)), cache_sh)
        donate = (1,)
    return fn, args, in_sh, out_sh, donate


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rules_override=None, tcfg=None, verbose=True,
             microbatches: int = 1) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    tcfg = tcfg or train_step_lib.TrainConfig(microbatches=microbatches,
                                              remat=True)
    rules = cell_rules(cfg, shape)
    rules.update(rules_override or {})

    t0 = time.time()
    with partitioning.use_mesh(mesh, rules):
        fn, args, in_sh, out_sh, donate = _sharding_trees(
            mesh, cfg, shape, tcfg)
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    mem = {k: int(getattr(ma, k, 0)) for k in
           ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")}
    hlo = compiled.as_text()
    counts = specs.param_count(cfg)
    rep = roofline.analyze(
        compiled, hlo, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=mesh.devices.size, n_active=counts["active"],
        tokens=shape.tokens, kind=("train" if shape.kind == "train"
                                   else "serve"),
        flash_min=roofline.flash_min_bytes(cfg, shape,
                                           mesh.devices.size))
    result = rep.to_dict()
    result.update({
        "raw_cost_analysis": roofline.raw_cost_analysis(compiled),
        "memory_analysis": mem,
        "params_total": counts["total"],
        "params_active": counts["active"],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "rules": {**partitioning.DEFAULT_RULES, **rules},
        "ok": True,
    })
    # live per-device bytes: arguments (state+cache live on device) + temps
    live = mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
    result["live_bytes_per_device"] = live
    result["fits_hbm_16g"] = bool(live < 16 * 1024**3)
    if verbose:
        print(f"[{mesh_name}] {arch} x {shape_name}: "
              f"compute={rep.compute_t*1e3:.2f}ms "
              f"memory={rep.memory_t*1e3:.2f}/{rep.memory_t_fused*1e3:.2f}ms"
              f"(raw/fused) coll={rep.collective_t*1e3:.2f}ms "
              f"bound={rep.bound} mfu={rep.mfu:.3f} "
              f"useful={rep.useful_flops_ratio:.2f} "
              f"live={live/1e9:.2f}GB/dev "
              f"(compile {t_compile:.0f}s)")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--rules", default="",
                    help="logical rule overrides k=v,k2=v2 (v empty=None)")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    rules_override = {}
    for kv in filter(None, args.rules.split(",")):
        k, _, v = kv.partition("=")
        rules_override[k] = v if v else None

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape))
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch, shape_name in cells:
        for mp in meshes:
            mesh_name = "multipod" if mp else "pod"
            tag = f"{arch}__{shape_name}__{mesh_name}"
            path = os.path.join(args.out, tag + ".json")
            if not cell_applicable(arch, shape_name):
                with open(path, "w") as f:
                    json.dump({"ok": True, "skipped": True,
                               "reason": "inapplicable (DESIGN.md §5)"}, f)
                print(f"[{mesh_name}] {arch} x {shape_name}: SKIP "
                      f"(documented)")
                n_skip += 1
                continue
            try:
                result = run_cell(arch, shape_name, multi_pod=mp,
                                  rules_override=rules_override or None,
                                  microbatches=args.microbatches)
                n_ok += 1
            except Exception as e:
                traceback.print_exc()
                result = {"ok": False, "error": f"{type(e).__name__}: {e}"}
                n_fail += 1
            with open(path, "w") as f:
                json.dump(result, f, indent=1)
    print(f"dry-run complete: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
