"""Dry-run profiler: per-op and top-instruction traffic breakdowns.

This is the "profile" for the §Perf hypothesis loop (no real-TPU
timings exist here): trip-scaled HBM bytes and FLOPs per op kind, plus
the heaviest individual instructions with their source metadata.

  PYTHONPATH=src python -m repro.launch.profile --arch deepseek-7b \
      --shape train_4k [--rules k=v,...]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import defaultdict

from repro.launch import hlo_cost


def per_op_breakdown(hlo: str):
    """-> (by_op dict, rows list of heaviest instrs)."""
    blocks, entry_name = hlo_cost.parse_blocks(hlo)
    entry = blocks.get(entry_name) or max(blocks.values(),
                                          key=lambda b: len(b.instrs))
    by_op = defaultdict(lambda: [0.0, 0.0])   # op -> [bytes, flops]
    rows = []

    def walk(bname, mult):
        b = blocks[bname]
        for ins in b.instrs:
            if ins.op in hlo_cost._FREE_OPS:
                continue
            if ins.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                t = hlo_cost._trip_count(ins, blocks)
                if mb and mb.group(1) in blocks:
                    walk(mb.group(1), mult * t)
                continue
            if ins.op in ("conditional", "call"):
                continue
            byt, out_b, op_b = hlo_cost.instr_traffic(ins, b, blocks)
            fl = 0.0
            if ins.op in ("dot", "convolution"):
                fl = hlo_cost._contraction_flops(ins, b.shapes)
            by_op[ins.op][0] += byt * mult
            by_op[ins.op][1] += fl * mult
            meta = re.search(r'op_name="([^"]*)"', ins.rest)
            rows.append((byt * mult, mult, ins.op, ins.shape[:64],
                         meta.group(1)[-90:] if meta else ""))

    walk(entry.name, 1)
    rows.sort(key=lambda r: -r[0])
    return dict(by_op), rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--rules", default="")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    import jax

    from repro.configs import SHAPES, get_config
    from repro.core import partitioning
    from repro.launch import dryrun
    from repro.launch.mesh import make_production_mesh
    from repro.train import step as tsl

    rules = {}
    for kv in filter(None, args.rules.split(",")):
        k, _, v = kv.partition("=")
        rules[k] = v if v else None

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
    cell_rules = dryrun.cell_rules(cfg, shape)
    cell_rules.update(rules)
    with partitioning.use_mesh(mesh, cell_rules):
        fn, fargs, in_sh, out_sh, donate = dryrun._sharding_trees(
            mesh, cfg, shape, tsl.TrainConfig())
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*fargs).compile()
    hlo = compiled.as_text()
    by_op, rows = per_op_breakdown(hlo)
    print(f"== per-op traffic ({args.arch} x {args.shape}, {args.mesh}) ==")
    for op, (byt, fl) in sorted(by_op.items(), key=lambda kv: -kv[1][0]):
        print(f"  {op:24s} {byt/1e9:10.1f} GB   {fl/1e12:8.2f} TFLOP")
    print(f"== top {args.top} instructions ==")
    for byt, mult, op, shp, meta in rows[:args.top]:
        print(f"  {byt/1e9:8.1f}GB x{mult:4d} {op:12s} {shp}")
        if meta:
            print(f"           {meta}")


if __name__ == "__main__":
    main()
