"""Self-speculative drafting: host-side prompt-lookup n-gram proposer.

No second model. The drafter treats each slot's token history (prompt +
generated tokens) as its own draft model: if the trailing n-gram of the
history has occurred earlier, the tokens that followed that earlier
occurrence are proposed as the next ``k`` draft tokens (prompt-lookup /
"self-speculative" decoding). Repetitive contexts — code, retrieval
answers, structured output — hit long matches and verify whole runs per
step; non-repetitive contexts simply propose nothing and the engine
falls back to plain decode, so the drafter never costs a device op.

Everything here is plain numpy over host token lists: proposals feed
the engine's batched verify step (``lm.verify_states``) which scores
the panel on-device, and acceptance happens inside the same jit. This
module must stay free of jax so the sync auditor can hold the serving
directory to its zero-device-sync budget.
"""
from __future__ import annotations

import numpy as np

# Longest trailing n-gram tried first; single-token fallback matches
# any earlier occurrence of the last token. Longer anchors make fewer,
# better proposals.
MAX_NGRAM = 3


def propose(history: np.ndarray, k: int, *,
            max_ngram: int = MAX_NGRAM) -> np.ndarray:
    """Propose up to ``k`` draft tokens continuing ``history``.

    Scans for the most recent earlier occurrence of the longest trailing
    n-gram (n = max_ngram down to 1) and returns the tokens that
    followed it, truncated to ``k`` and to the available continuation.
    Returns an empty array when no anchor matches — the caller should
    fall back to plain decode for that slot.
    """
    hist = np.asarray(history, dtype=np.int32).ravel()
    t = hist.size
    if k <= 0 or t < 2:
        return np.zeros((0,), np.int32)
    for n in range(min(max_ngram, t - 1), 0, -1):
        tail = hist[t - n:]
        # candidate start positions for an earlier occurrence; the match
        # must end before the tail itself so the continuation is real
        windows = np.lib.stride_tricks.sliding_window_view(
            hist[:t - 1], n)
        hits = np.flatnonzero((windows == tail[None, :]).all(axis=1))
        if hits.size == 0:
            continue
        start = int(hits[-1]) + n          # most recent match wins
        stop = min(start + k, t)
        if stop > start:
            return hist[start:stop].astype(np.int32, copy=True)
    return np.zeros((0,), np.int32)
