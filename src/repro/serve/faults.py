"""Deterministic fault injection for the serving engine (chaos layer).

Production serving has to survive the failures it cannot prevent:
allocator exhaustion, numerically poisoned batches, lost device
buffers, stalled steps. This module makes those failures *injectable
and reproducible* so the engine's recovery paths are exercised by CI
instead of discovered in production.

A :class:`FaultPlan` is a seeded, immutable schedule of faults keyed by
the engine's monotonic step clock (one tick per ``Engine.run`` loop
iteration, monotonic across runs). The engine consults the plan at four
hook points:

  * ``alloc`` — the next :class:`~repro.serve.paging.PagePool` page
    draw in that step raises :class:`AllocFault` (simulating allocator
    exhaustion mid-``ensure``; the engine's admission transaction rolls
    the pool back);
  * ``nan``   — the decode step's logits for one slot (or all slots)
    are overwritten with NaN *inside the jitted step* via a traced
    poison mask, so the engine's in-graph NaN guard trips exactly the
    way a real numeric blow-up would;
  * ``exc``   — the step raises :class:`StepFault` before dispatch,
    standing in for a mid-step device error that invalidates the
    donated cache buffer (the engine must rebuild device state);
  * ``slow``  — the step sleeps, standing in for a straggler device so
    deadline enforcement can be tested deterministically.

Plans are pure schedules: the same plan driven through the same engine
traffic injects the same faults. Build one explicitly
(:func:`FaultPlan.from_specs` / :func:`parse_plan`) or randomly but
reproducibly (:func:`FaultPlan.random`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

KINDS = ("alloc", "nan", "exc", "slow")


class FaultError(RuntimeError):
    """Base class of injected faults (never raised by real failures, so
    tests can tell injected faults from genuine bugs)."""


class AllocFault(FaultError):
    """Injected page-allocation failure (pool pressure chaos)."""


class StepFault(FaultError):
    """Injected mid-step device error (donated buffers presumed lost)."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    kind : 'alloc' | 'nan' | 'exc' | 'slow'
    step : engine clock tick (run-loop iteration, monotonic across runs)
    slot : for 'nan': the poisoned slot, or None => every active slot
    arg  : for 'slow': sleep seconds
    """
    kind: str
    step: int
    slot: Optional[int] = None
    arg: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")


class FaultPlan:
    """An immutable, queryable schedule of :class:`Fault` entries."""

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults: Tuple[Fault, ...] = tuple(
            sorted(faults, key=lambda f: (f.step, KINDS.index(f.kind),
                                          -1 if f.slot is None else f.slot)))
        self._by_step: Dict[int, List[Fault]] = {}
        for f in self.faults:
            self._by_step.setdefault(f.step, []).append(f)

    # -- constructors --------------------------------------------------

    @classmethod
    def from_specs(cls, *specs) -> "FaultPlan":
        return cls([s if isinstance(s, Fault) else Fault(**s)
                    for s in specs])

    @classmethod
    def random(cls, seed: int, n_steps: int, *, n_slots: int = 4,
               p_alloc: float = 0.0, p_nan: float = 0.0,
               p_exc: float = 0.0, p_slow: float = 0.0,
               slow_s: float = 1e-3) -> "FaultPlan":
        """Reproducible random schedule: same seed => same plan."""
        rng = np.random.default_rng(seed)
        faults: List[Fault] = []
        for step in range(n_steps):
            draws = rng.random(4)
            if draws[0] < p_alloc:
                faults.append(Fault("alloc", step))
            if draws[1] < p_nan:
                faults.append(Fault("nan", step,
                                    slot=int(rng.integers(n_slots))))
            if draws[2] < p_exc:
                faults.append(Fault("exc", step))
            if draws[3] < p_slow:
                faults.append(Fault("slow", step, arg=slow_s))
        return cls(faults)

    # -- queries (all pure) --------------------------------------------

    def at(self, step: int) -> List[Fault]:
        return list(self._by_step.get(step, ()))

    def alloc_fails(self, step: int) -> bool:
        return any(f.kind == "alloc" for f in self.at(step))

    def poison_slots(self, step: int) -> Optional[List[Optional[int]]]:
        """Slots whose decode logits are NaN-poisoned this step (None
        inside the list = every active slot); None = no poisoning."""
        s = [f.slot for f in self.at(step) if f.kind == "nan"]
        return s or None

    def step_raises(self, step: int) -> bool:
        return any(f.kind == "exc" for f in self.at(step))

    def slow_s(self, step: int) -> float:
        return sum(f.arg for f in self.at(step) if f.kind == "slow")

    def max_step(self) -> int:
        return max((f.step for f in self.faults), default=-1)

    def describe(self) -> str:
        if not self.faults:
            return "no faults"
        return ",".join(
            f"{f.kind}@{f.step}"
            + (f".{f.slot}" if f.slot is not None else "")
            + (f":{f.arg:g}" if f.arg else "")
            for f in self.faults)

    def __len__(self) -> int:
        return len(self.faults)

    def __eq__(self, other) -> bool:
        return (isinstance(other, FaultPlan)
                and self.faults == other.faults)

    def __repr__(self) -> str:
        return f"FaultPlan({self.describe()})"


def parse_plan(text: str) -> FaultPlan:
    """Parse the ``--fault-plan`` CLI DSL: a comma-separated list of
    ``kind@step``, ``nan@step.slot`` and ``slow@step:seconds`` entries,
    e.g. ``"alloc@3,nan@5.1,exc@7,slow@2:0.01"``. Empty string => no
    faults."""
    text = (text or "").strip()
    if not text:
        return FaultPlan()
    faults = []
    for item in text.split(","):
        item = item.strip()
        if not item:
            continue
        try:
            kind, _, rest = item.partition("@")
            arg = 0.0
            if ":" in rest:
                rest, _, a = rest.partition(":")
                arg = float(a)
            slot: Optional[int] = None
            if "." in rest:
                rest, _, sl = rest.partition(".")
                slot = int(sl)
            faults.append(Fault(kind.strip(), int(rest), slot=slot,
                                arg=arg))
        except (ValueError, TypeError) as e:
            raise ValueError(
                f"bad --fault-plan entry {item!r}: expected "
                "kind@step[.slot][:arg] with kind in "
                f"{KINDS} ({e})") from None
    return FaultPlan(faults)
