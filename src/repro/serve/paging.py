"""Host-side paged-KV bookkeeping: page allocator + prefill buckets.

The device side (``models/lm.py`` / ``models/attention.py``) only ever
sees a page *pool* per attention layer and a per-slot block table; this
module owns the mutable host state that fills those tables:

  * :class:`PagePool` — a free-list allocator over the physical pages.
    Admission is *reservation-based*: a request is admitted only when
    the pool can cover its worst-case length (prompt + max_new, capped
    at max_len), so decode can allocate tail pages lazily and never
    deadlocks mid-sequence. Retiring a slot returns its pages to the
    free list and points its table row back at the slot's private
    scratch page.
  * bucket policy — prompts are padded to a small static set of lengths
    (powers of two up to max_len) so continuous batching compiles
    O(n_buckets) prefill programs instead of O(unique prompt lengths).

The pool is *transactional*: :meth:`PagePool.begin` snapshots the full
allocator state and :meth:`PagePool.rollback` restores it, so a
multi-step mutation (admission's admit+ensure, a speculative-decode
draft's tail growth) either lands completely or not at all —
allocation failures and preemption roll back instead of leaking pages.
:meth:`PagePool.rollback_tail` is the fine-grained form: return just a
slot's tail pages past a token count (rejected speculative drafts,
preempted requests keeping nothing).
"""
from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from repro.core.types import ModelConfig


def default_buckets(max_len: int, min_bucket: int = 16) -> List[int]:
    """Power-of-two prefill padding lengths: min_bucket, ..., max_len."""
    out, b = [], min_bucket
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


def bucket_for(plen: int, buckets: List[int]) -> int:
    """Smallest bucket covering a prompt of length ``plen``."""
    for b in buckets:
        if plen <= b:
            return b
    raise ValueError(f"prompt length {plen} exceeds largest bucket "
                     f"{buckets[-1]}")


def chunk_schedule(plen: int, chunk_size: int,
                   buckets: List[int]) -> List[tuple]:
    """Chunked-prefill schedule for a prompt of length ``plen``:
    ``[(offset, chunk_len, padded_shape), ...]``.

    Full chunks run at the ``chunk_size`` shape; the final partial chunk
    pads to the smallest covering bucket — ``chunk_size`` sits on the
    bucket ladder, so every chunk shape is a ladder entry at or below
    it and mixed chunked/unchunked traffic compiles at most
    ``n_buckets + n_chunk_shapes + 1`` programs (one-shot buckets +
    chunk shapes + the decode step)."""
    out, off = [], 0
    while off < plen:
        clen = min(chunk_size, plen - off)
        shape = (chunk_size if clen == chunk_size
                 else bucket_for(clen, buckets))
        out.append((off, clen, shape))
        off += clen
    return out


def supports_bucketing(cfg: ModelConfig) -> bool:
    """Tail-padding a prompt is exact only when every position's state
    is causal-attention KV: recurrent mixers (mamba/rwkv) fold the pad
    tokens into their running state, MoE token-choice routing competes
    padding against real tokens for expert capacity, and enc-dec /
    vision frontends consume positional extras. Those archs prefill at
    exact lengths instead (one compile per distinct prompt length)."""
    if cfg.encdec or cfg.frontend != "none" or cfg.moe is not None:
        return False
    return all(blk.mixer == "attn" and blk.ffn in ("mlp", "none")
               and not blk.cross_attn
               for stage in cfg.stages() for blk in stage.body)


def page_aligned_size(page_size: int, cfg: ModelConfig) -> int:
    """Largest size <= page_size dividing every sliding window in cfg
    (ring pages must tile the window exactly)."""
    ps = page_size
    for stage in cfg.stages():
        for blk in stage.body:
            if blk.mixer == "attn" and blk.window:
                ps = int(np.gcd(ps, blk.window))
    return max(ps, 1)


class PagePool:
    """Free-list page allocator with per-slot block tables.

    Physical ids 0..n_pages-1 are real pages; ids ``n_pages + slot`` are
    per-slot *scratch* pages idle table entries point at (lockstep
    decode writes from retired or mid-prefill slots land there). Each
    slot owns its scratch row, so idle-slot writes target disjoint
    storage instead of serializing on one shared trash page — XLA can
    overlap (or drop) them. ``tables`` is the host mirror the engine
    ships to the device each time it changes.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_pages: int):
        self.n_pages, self.page_size = n_pages, page_size
        self.scratch = n_pages + np.arange(n_slots, dtype=np.int64)
        self.free: List[int] = list(range(n_pages - 1, -1, -1))
        self.tables = np.repeat(self.scratch[:, None], max_pages,
                                axis=1).astype(np.int32)
        self.n_alloc = np.zeros(n_slots, np.int64)
        self.reserved = np.zeros(n_slots, np.int64)
        self.version = 0              # bumped on any table change
        # Fault-injection seam: called before every free-list draw; may
        # raise to simulate allocator exhaustion (see serve/faults.py).
        self.alloc_hook: Optional[Callable[[], None]] = None
        self._snapshots: List[tuple] = []

    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        """True when the free list can cover a worst-case ``n_tokens``
        sequence on top of every live slot's outstanding reservation."""
        outstanding = int((self.reserved - self.n_alloc).sum())
        return len(self.free) - outstanding >= self._pages_for(n_tokens)

    def admit(self, slot: int, n_tokens: int) -> None:
        """Reserve worst-case capacity for a slot (caller checked
        :meth:`can_admit`); pages are drawn lazily by :meth:`ensure`."""
        assert self.n_alloc[slot] == 0 and self.reserved[slot] == 0
        self.reserved[slot] = self._pages_for(n_tokens)

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow the slot's table to cover ``n_tokens`` positions."""
        need = min(self._pages_for(n_tokens), self.tables.shape[1])
        while self.n_alloc[slot] < need:
            if self.alloc_hook is not None:
                self.alloc_hook()
            self.tables[slot, self.n_alloc[slot]] = self.free.pop()
            self.n_alloc[slot] += 1
            self.version += 1

    def release(self, slot: int) -> None:
        """Retire a slot: pages back to the free list, table back to the
        slot's scratch page."""
        n = int(self.n_alloc[slot])
        self.free.extend(int(p) for p in self.tables[slot, :n])
        self.tables[slot, :] = self.scratch[slot]
        self.n_alloc[slot] = 0
        self.reserved[slot] = 0
        self.version += 1

    def live_pages(self) -> int:
        return int(self.n_alloc.sum())

    # -- transactions --------------------------------------------------
    #
    # begin/commit/rollback bracket multi-step mutations (admission's
    # admit+ensure pair, speculative tail growth) so a failure midway —
    # injected or real — restores the exact prior allocator state
    # instead of leaking half an admission. Snapshots nest (LIFO).

    def begin(self) -> None:
        """Open a transaction: snapshot free list, tables, counters."""
        self._snapshots.append((list(self.free), self.tables.copy(),
                                self.n_alloc.copy(),
                                self.reserved.copy()))

    def commit(self) -> None:
        """Close the innermost transaction, keeping its mutations."""
        self._snapshots.pop()

    def rollback(self) -> None:
        """Abort the innermost transaction, restoring its snapshot.

        ``version`` still bumps monotonically — consumers key shipped
        block tables on it, and a rollback changes the tables even
        though it *restores* them, so reuse of a pre-transaction
        version number would leave stale device tables in place.
        """
        free, tables, n_alloc, reserved = self._snapshots.pop()
        self.free, self.tables = free, tables
        self.n_alloc, self.reserved = n_alloc, reserved
        self.version += 1

    def in_transaction(self) -> bool:
        return bool(self._snapshots)

    def rollback_tail(self, slot: int, n_tokens: int) -> int:
        """Shrink a slot's allocation back to ``n_tokens`` positions,
        returning tail pages to the free list (rejected speculative
        drafts; ``n_tokens=0`` strips a preempted slot bare while its
        reservation survives for re-admission). Returns the number of
        pages freed. The reservation is *not* shrunk: the sequence's
        worst case is unchanged by dropping its tail."""
        keep = self._pages_for(n_tokens)
        freed = 0
        while self.n_alloc[slot] > keep:
            self.n_alloc[slot] -= 1
            self.free.append(int(self.tables[slot, self.n_alloc[slot]]))
            self.tables[slot, self.n_alloc[slot]] = self.scratch[slot]
            freed += 1
            self.version += 1
        return freed

    def check_conservation(self) -> None:
        """Assert the allocator invariants: every physical page is
        exactly-once free or live, and no page id appears twice."""
        live = [int(p) for s in range(self.tables.shape[0])
                for p in self.tables[s, :int(self.n_alloc[s])]]
        assert len(self.free) + len(live) == self.n_pages, (
            f"page leak: {len(self.free)} free + {len(live)} live != "
            f"{self.n_pages}")
        seen = self.free + live
        assert len(set(seen)) == len(seen), "double-allocated page"
        assert set(seen) == set(range(self.n_pages)), "foreign page id"
