"""Host-side paged-KV bookkeeping: page allocator + prefill buckets.

The device side (``models/lm.py`` / ``models/attention.py``) only ever
sees a page *pool* per attention layer and a per-slot block table; this
module owns the mutable host state that fills those tables:

  * :class:`PagePool` — a free-list allocator over the physical pages.
    Admission is *reservation-based*: a request is admitted only when
    the pool can cover its worst-case length (prompt + max_new, capped
    at max_len), so decode can allocate tail pages lazily and never
    deadlocks mid-sequence. Retiring a slot returns its pages to the
    free list and points its table row back at the slot's private
    scratch page.
  * bucket policy — prompts are padded to a small static set of lengths
    (powers of two up to max_len) so continuous batching compiles
    O(n_buckets) prefill programs instead of O(unique prompt lengths).

The pool is *transactional*: :meth:`PagePool.begin` snapshots the full
allocator state and :meth:`PagePool.rollback` restores it, so a
multi-step mutation (admission's admit+ensure, a speculative-decode
draft's tail growth) either lands completely or not at all —
allocation failures and preemption roll back instead of leaking pages.
:meth:`PagePool.rollback_tail` is the fine-grained form: return just a
slot's tail pages past a token count (rejected speculative drafts,
preempted requests keeping nothing).

Pages are *refcounted* (PR 8): the prefix cache
(``serve/prefix_cache.py``) maps one physical page into many block
tables — and holds its own reference — so a page returns to the free
list only when its last reference drops. :meth:`PagePool.map_shared`
appends existing pages to a slot's table (refcount++),
:meth:`PagePool.cow` remaps a shared table entry to a freshly drawn
private page (copy-on-write; a sole-owner page is written in place
instead), and :meth:`PagePool.deref` is how the cache releases an
evicted branch. A ``reclaimer`` (the cache) extends
:meth:`can_admit`'s notion of "available" with LRU-evictable cached
pages; evictions themselves must happen OUTSIDE transactions — a
rollback restores refcounts but cannot resurrect a dropped tree node.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from repro.core.types import ModelConfig


def default_buckets(max_len: int, min_bucket: int = 16) -> List[int]:
    """Power-of-two prefill padding lengths: min_bucket, ..., max_len."""
    out, b = [], min_bucket
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


def bucket_for(plen: int, buckets: List[int]) -> int:
    """Smallest bucket covering a prompt of length ``plen``."""
    for b in buckets:
        if plen <= b:
            return b
    raise ValueError(f"prompt length {plen} exceeds largest bucket "
                     f"{buckets[-1]}")


def chunk_schedule(plen: int, chunk_size: int,
                   buckets: List[int]) -> List[tuple]:
    """Chunked-prefill schedule for a prompt of length ``plen``:
    ``[(offset, chunk_len, padded_shape), ...]``.

    Full chunks run at the ``chunk_size`` shape; the final partial chunk
    pads to the smallest covering bucket — ``chunk_size`` sits on the
    bucket ladder, so every chunk shape is a ladder entry at or below
    it and mixed chunked/unchunked traffic compiles at most
    ``n_buckets + n_chunk_shapes + 1`` programs (one-shot buckets +
    chunk shapes + the decode step)."""
    out, off = [], 0
    while off < plen:
        clen = min(chunk_size, plen - off)
        shape = (chunk_size if clen == chunk_size
                 else bucket_for(clen, buckets))
        out.append((off, clen, shape))
        off += clen
    return out


def spec_ladder(k_max: int) -> List[int]:
    """Documented draft-width ladder for speculative decode: power-of-two
    widths 1, 2, ..., 2^ceil(log2(k_max)). A speculative step pads its
    widest per-slot draft up to the next ladder entry (true per-slot
    lengths travel in a traced ``draft_len`` operand), so the verify
    program compiles once per ladder entry — the compile bound grows by
    ``len(spec_ladder(k))`` and by nothing else (enforced by the
    ``compile_bound`` auditor pass)."""
    if k_max <= 0:
        return []
    return [1 << i for i in range((k_max - 1).bit_length() + 1)]


def supports_bucketing(cfg: ModelConfig) -> bool:
    """Tail-padding a prompt is exact only when every position's state
    is causal-attention KV: recurrent mixers (mamba/rwkv) fold the pad
    tokens into their running state, MoE token-choice routing competes
    padding against real tokens for expert capacity, and enc-dec /
    vision frontends consume positional extras. Those archs prefill at
    exact lengths instead (one compile per distinct prompt length)."""
    if cfg.encdec or cfg.frontend != "none" or cfg.moe is not None:
        return False
    return all(blk.mixer == "attn" and blk.ffn in ("mlp", "none")
               and not blk.cross_attn
               for stage in cfg.stages() for blk in stage.body)


def page_aligned_size(page_size: int, cfg: ModelConfig) -> int:
    """Largest size <= page_size dividing every sliding window in cfg
    (ring pages must tile the window exactly)."""
    ps = page_size
    for stage in cfg.stages():
        for blk in stage.body:
            if blk.mixer == "attn" and blk.window:
                ps = int(np.gcd(ps, blk.window))
    return max(ps, 1)


class PagePool:
    """Free-list page allocator with per-slot block tables.

    Physical ids 0..n_pages-1 are real pages; ids ``n_pages + slot`` are
    per-slot *scratch* pages idle table entries point at (lockstep
    decode writes from retired or mid-prefill slots land there). Each
    slot owns its scratch row, so idle-slot writes target disjoint
    storage instead of serializing on one shared trash page — XLA can
    overlap (or drop) them. ``tables`` is the host mirror the engine
    ships to the device each time it changes.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_pages: int):
        self.n_pages, self.page_size = n_pages, page_size
        self.scratch = n_pages + np.arange(n_slots, dtype=np.int64)
        self.free: List[int] = list(range(n_pages - 1, -1, -1))
        self.tables = np.repeat(self.scratch[:, None], max_pages,
                                axis=1).astype(np.int32)
        self.n_alloc = np.zeros(n_slots, np.int64)
        self.reserved = np.zeros(n_slots, np.int64)
        # per-page reference counts: #block-table rows naming the page
        # plus one per prefix-cache node holding it
        self.refs = np.zeros(n_pages, np.int64)
        # logical index of a slot's COW-pending shared page (-1 = none):
        # the page counts in n_alloc but its private replacement is a
        # draw the reservation must still cover (see can_admit_pages)
        self.cow_idx = np.full(n_slots, -1, np.int64)
        self.version = 0              # bumped on any table change
        # Fault-injection seam: called before every free-list draw; may
        # raise to simulate allocator exhaustion (see serve/faults.py).
        self.alloc_hook: Optional[Callable[[], None]] = None
        # Optional prefix cache: evictable() widens can_admit's notion
        # of available pages with LRU-reclaimable cached branches
        self.reclaimer = None
        self._snapshots: List[tuple] = []

    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def available(self) -> int:
        """Pages admission may count on: the free list plus whatever the
        reclaimer (prefix cache) could evict under pressure."""
        extra = self.reclaimer.evictable() if self.reclaimer else 0
        return len(self.free) + extra

    def can_admit_pages(self, n_pages: int) -> bool:
        """True when ``n_pages`` fresh pages fit on top of every live
        slot's outstanding reservation (lazily-drawn remainder plus one
        owed private copy per COW-pending shared page)."""
        outstanding = int((self.reserved - self.n_alloc).sum()
                          + (self.cow_idx >= 0).sum())
        return self.available() - outstanding >= n_pages

    def can_admit(self, n_tokens: int) -> bool:
        """True when the pool can cover a worst-case ``n_tokens``
        sequence on top of every live slot's outstanding reservation."""
        return self.can_admit_pages(self._pages_for(n_tokens))

    def admit(self, slot: int, n_tokens: int) -> None:
        """Reserve worst-case capacity for a slot (caller checked
        :meth:`can_admit`); pages are drawn lazily by :meth:`ensure`."""
        assert self.n_alloc[slot] == 0 and self.reserved[slot] == 0
        self.reserved[slot] = self._pages_for(n_tokens)

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow the slot's table to cover ``n_tokens`` positions."""
        need = min(self._pages_for(n_tokens), self.tables.shape[1])
        while self.n_alloc[slot] < need:
            if self.alloc_hook is not None:
                self.alloc_hook()
            page = self.free.pop()
            self.refs[page] = 1
            self.tables[slot, self.n_alloc[slot]] = page
            self.n_alloc[slot] += 1
            self.version += 1

    def map_shared(self, slot: int, pages, cow_tail: bool = False) -> None:
        """Append already-referenced pages (a prefix-cache hit) to the
        slot's table: refcount++ per page, no free-list draw. With
        ``cow_tail`` the last mapped page is only *partially* covered by
        the slot's prompt — it is copy-on-write pending (:meth:`cow`
        must remap it before the first write into its range), and its
        private replacement stays charged against the reservation."""
        for p in pages:
            p = int(p)
            assert 0 <= p < self.n_pages and self.refs[p] >= 1, (
                f"mapping unreferenced page {p}")
            self.refs[p] += 1
            self.tables[slot, self.n_alloc[slot]] = p
            self.n_alloc[slot] += 1
            self.version += 1
        if cow_tail:
            assert pages, "cow_tail without mapped pages"
            self.cow_idx[slot] = self.n_alloc[slot] - 1

    def cow(self, slot: int, logical: int) -> tuple:
        """Copy-on-write a slot's table entry before its first write:
        draw a private page, remap the row, drop one reference on the
        shared original (the device copies the kept prefix rows —
        ``lm.cow_copy``). Returns ``(src, dst)``; a sole-owner page
        (refcount 1) is written in place instead — ``src == dst`` and
        nothing is drawn."""
        src = int(self.tables[slot, logical])
        assert logical < self.n_alloc[slot] and src < self.n_pages
        if self.cow_idx[slot] == logical:
            self.cow_idx[slot] = -1
        if self.refs[src] == 1:
            return src, src
        if self.alloc_hook is not None:
            self.alloc_hook()
        dst = self.free.pop()
        self.refs[dst] = 1
        self.refs[src] -= 1
        self.tables[slot, logical] = dst
        self.version += 1
        return src, dst

    def ref_page(self, page: int) -> None:
        """Take a reference on a live page (a prefix-cache node adopting
        a slot's written prompt page)."""
        assert self.refs[page] >= 1, f"ref on dead page {page}"
        self.refs[page] += 1

    def deref(self, page: int) -> bool:
        """Drop one reference; the page returns to the free list only
        when the last reference drops (returns True then)."""
        self.refs[page] -= 1
        assert self.refs[page] >= 0, f"refcount underflow on page {page}"
        if self.refs[page] == 0:
            self.free.append(int(page))
            return True
        return False

    def release(self, slot: int) -> None:
        """Retire a slot: drop one reference per table entry (pages the
        prefix cache still holds stay allocated), table back to the
        slot's scratch page."""
        n = int(self.n_alloc[slot])
        for p in self.tables[slot, :n]:
            self.deref(int(p))
        self.tables[slot, :] = self.scratch[slot]
        self.n_alloc[slot] = 0
        self.reserved[slot] = 0
        self.cow_idx[slot] = -1
        self.version += 1

    def live_pages(self) -> int:
        """Table-mapped logical pages (shared pages count once per slot
        mapping them — the gather-volume view the engine prices)."""
        return int(self.n_alloc.sum())

    def unique_live(self) -> int:
        """Distinct referenced physical pages (the occupancy view)."""
        return self.n_pages - len(self.free)

    # -- transactions --------------------------------------------------
    #
    # begin/commit/rollback bracket multi-step mutations (admission's
    # admit+ensure pair, speculative tail growth) so a failure midway —
    # injected or real — restores the exact prior allocator state
    # instead of leaking half an admission. Snapshots nest (LIFO).

    def begin(self) -> None:
        """Open a transaction: snapshot free list, tables, counters."""
        self._snapshots.append((list(self.free), self.tables.copy(),
                                self.n_alloc.copy(),
                                self.reserved.copy(), self.refs.copy(),
                                self.cow_idx.copy()))

    def commit(self) -> None:
        """Close the innermost transaction, keeping its mutations."""
        self._snapshots.pop()

    def rollback(self) -> None:
        """Abort the innermost transaction, restoring its snapshot.

        ``version`` still bumps monotonically — consumers key shipped
        block tables on it, and a rollback changes the tables even
        though it *restores* them, so reuse of a pre-transaction
        version number would leave stale device tables in place.

        Refcounts restore with the rest of the state, which is why
        prefix-cache evictions must happen *before* ``begin``: a
        rollback cannot resurrect the tree node that held the
        reference, so an in-transaction eviction would strand the
        restored refcount forever.
        """
        (free, tables, n_alloc, reserved, refs,
         cow_idx) = self._snapshots.pop()
        self.free, self.tables = free, tables
        self.n_alloc, self.reserved = n_alloc, reserved
        self.refs, self.cow_idx = refs, cow_idx
        self.version += 1

    def in_transaction(self) -> bool:
        return bool(self._snapshots)

    def rollback_tail(self, slot: int, n_tokens: int) -> int:
        """Shrink a slot's allocation back to ``n_tokens`` positions,
        returning tail pages to the free list (rejected speculative
        drafts; ``n_tokens=0`` strips a preempted slot bare while its
        reservation survives for re-admission). Returns the number of
        pages freed. The reservation is *not* shrunk: the sequence's
        worst case is unchanged by dropping its tail. Shared
        (prefix-cache) tail pages only lose this slot's reference —
        ``freed`` counts pages actually returned to the free list."""
        keep = self._pages_for(n_tokens)
        freed = 0
        while self.n_alloc[slot] > keep:
            self.n_alloc[slot] -= 1
            if self.deref(int(self.tables[slot, self.n_alloc[slot]])):
                freed += 1
            self.tables[slot, self.n_alloc[slot]] = self.scratch[slot]
            self.version += 1
        if self.cow_idx[slot] >= self.n_alloc[slot]:
            self.cow_idx[slot] = -1
        return freed

    def check_conservation(self) -> None:
        """Assert the allocator invariants under refcounting: every
        physical page is exactly-once free (refcount 0) or referenced
        (refcount ≥ 1), the free list holds no duplicates, and no block
        table names a page more often than its refcount covers."""
        assert len(self.free) == len(set(self.free)), "double-freed page"
        assert all(0 <= p < self.n_pages for p in self.free), (
            "foreign page id on free list")
        referenced = int((self.refs > 0).sum())
        assert len(self.free) + referenced == self.n_pages, (
            f"page leak: {len(self.free)} free + {referenced} "
            f"referenced != {self.n_pages}")
        assert all(self.refs[p] == 0 for p in self.free), (
            "free page with live refcount")
        mult = np.zeros(self.n_pages, np.int64)
        for s in range(self.tables.shape[0]):
            for p in self.tables[s, :int(self.n_alloc[s])]:
                assert 0 <= p < self.n_pages, "foreign page id in table"
                mult[int(p)] += 1
        assert (mult <= self.refs).all(), (
            "table names a page beyond its refcount")
