"""Host-side paged-KV bookkeeping: page allocator + prefill buckets.

The device side (``models/lm.py`` / ``models/attention.py``) only ever
sees a page *pool* per attention layer and a per-slot block table; this
module owns the mutable host state that fills those tables:

  * :class:`PagePool` — a free-list allocator over the physical pages.
    Admission is *reservation-based*: a request is admitted only when
    the pool can cover its worst-case length (prompt + max_new, capped
    at max_len), so decode can allocate tail pages lazily and never
    deadlocks mid-sequence. Retiring a slot returns its pages to the
    free list and points its table row back at the slot's private
    scratch page.
  * bucket policy — prompts are padded to a small static set of lengths
    (powers of two up to max_len) so continuous batching compiles
    O(n_buckets) prefill programs instead of O(unique prompt lengths).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.types import ModelConfig


def default_buckets(max_len: int, min_bucket: int = 16) -> List[int]:
    """Power-of-two prefill padding lengths: min_bucket, ..., max_len."""
    out, b = [], min_bucket
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return out


def bucket_for(plen: int, buckets: List[int]) -> int:
    """Smallest bucket covering a prompt of length ``plen``."""
    for b in buckets:
        if plen <= b:
            return b
    raise ValueError(f"prompt length {plen} exceeds largest bucket "
                     f"{buckets[-1]}")


def chunk_schedule(plen: int, chunk_size: int,
                   buckets: List[int]) -> List[tuple]:
    """Chunked-prefill schedule for a prompt of length ``plen``:
    ``[(offset, chunk_len, padded_shape), ...]``.

    Full chunks run at the ``chunk_size`` shape; the final partial chunk
    pads to the smallest covering bucket — ``chunk_size`` sits on the
    bucket ladder, so every chunk shape is a ladder entry at or below
    it and mixed chunked/unchunked traffic compiles at most
    ``n_buckets + n_chunk_shapes + 1`` programs (one-shot buckets +
    chunk shapes + the decode step)."""
    out, off = [], 0
    while off < plen:
        clen = min(chunk_size, plen - off)
        shape = (chunk_size if clen == chunk_size
                 else bucket_for(clen, buckets))
        out.append((off, clen, shape))
        off += clen
    return out


def supports_bucketing(cfg: ModelConfig) -> bool:
    """Tail-padding a prompt is exact only when every position's state
    is causal-attention KV: recurrent mixers (mamba/rwkv) fold the pad
    tokens into their running state, MoE token-choice routing competes
    padding against real tokens for expert capacity, and enc-dec /
    vision frontends consume positional extras. Those archs prefill at
    exact lengths instead (one compile per distinct prompt length)."""
    if cfg.encdec or cfg.frontend != "none" or cfg.moe is not None:
        return False
    return all(blk.mixer == "attn" and blk.ffn in ("mlp", "none")
               and not blk.cross_attn
               for stage in cfg.stages() for blk in stage.body)


def page_aligned_size(page_size: int, cfg: ModelConfig) -> int:
    """Largest size <= page_size dividing every sliding window in cfg
    (ring pages must tile the window exactly)."""
    ps = page_size
    for stage in cfg.stages():
        for blk in stage.body:
            if blk.mixer == "attn" and blk.window:
                ps = int(np.gcd(ps, blk.window))
    return max(ps, 1)


class PagePool:
    """Free-list page allocator with per-slot block tables.

    Physical ids 0..n_pages-1 are real pages; ids ``n_pages + slot`` are
    per-slot *scratch* pages idle table entries point at (lockstep
    decode writes from retired or mid-prefill slots land there). Each
    slot owns its scratch row, so idle-slot writes target disjoint
    storage instead of serializing on one shared trash page — XLA can
    overlap (or drop) them. ``tables`` is the host mirror the engine
    ships to the device each time it changes.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 max_pages: int):
        self.n_pages, self.page_size = n_pages, page_size
        self.scratch = n_pages + np.arange(n_slots, dtype=np.int64)
        self.free: List[int] = list(range(n_pages - 1, -1, -1))
        self.tables = np.repeat(self.scratch[:, None], max_pages,
                                axis=1).astype(np.int32)
        self.n_alloc = np.zeros(n_slots, np.int64)
        self.reserved = np.zeros(n_slots, np.int64)
        self.version = 0              # bumped on any table change

    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        """True when the free list can cover a worst-case ``n_tokens``
        sequence on top of every live slot's outstanding reservation."""
        outstanding = int((self.reserved - self.n_alloc).sum())
        return len(self.free) - outstanding >= self._pages_for(n_tokens)

    def admit(self, slot: int, n_tokens: int) -> None:
        """Reserve worst-case capacity for a slot (caller checked
        :meth:`can_admit`); pages are drawn lazily by :meth:`ensure`."""
        assert self.n_alloc[slot] == 0 and self.reserved[slot] == 0
        self.reserved[slot] = self._pages_for(n_tokens)

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow the slot's table to cover ``n_tokens`` positions."""
        need = min(self._pages_for(n_tokens), self.tables.shape[1])
        while self.n_alloc[slot] < need:
            self.tables[slot, self.n_alloc[slot]] = self.free.pop()
            self.n_alloc[slot] += 1
            self.version += 1

    def release(self, slot: int) -> None:
        """Retire a slot: pages back to the free list, table back to the
        slot's scratch page."""
        n = int(self.n_alloc[slot])
        self.free.extend(int(p) for p in self.tables[slot, :n])
        self.tables[slot, :] = self.scratch[slot]
        self.n_alloc[slot] = 0
        self.reserved[slot] = 0
        self.version += 1

    def live_pages(self) -> int:
        return int(self.n_alloc.sum())
