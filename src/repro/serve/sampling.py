"""Token sampling: greedy, temperature, top-k, nucleus."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jnp.ndarray, key, *, temperature=1.0,
           top_k: int = 0, top_p: float = 1.0) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32.

    ``temperature`` may be a python float or a per-row (B,) array —
    continuous batching mixes greedy and sampled requests in one
    lockstep step, and a traced temperature operand keeps that a single
    compiled program. Rows with temperature <= 0 decode greedily.
    """
    if jnp.ndim(temperature) == 0 and not isinstance(temperature,
                                                     jax.core.Tracer):
        temperature = float(temperature)     # 0-d np/jnp scalars
    per_row = not isinstance(temperature, (int, float))
    if not per_row:
        if temperature <= 0.0:
            return greedy(logits)
        logits = logits / temperature
    else:
        # (B,) array or traced scalar: keep one compiled program with
        # the where-based greedy fallback per row
        t = jnp.broadcast_to(jnp.asarray(temperature, logits.dtype),
                             logits.shape[:1])
        raw = logits
        logits = logits / jnp.maximum(t, 1e-6)[:, None]
    if top_k > 0:
        # clamp to the vocab size: top_k >= V keeps every token (the
        # unclamped static index -top_k was out of bounds and raised)
        k = min(int(top_k), logits.shape[-1])
        if k < logits.shape[-1]:
            kth = jnp.sort(logits, axis=-1)[:, -k][:, None]
            logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    toks = jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
    if per_row:
        return jnp.where(t <= 0.0, greedy(raw), toks)
    return toks
