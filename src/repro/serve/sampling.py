"""Token sampling: greedy, temperature, top-k, nucleus."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jnp.ndarray, key, *, temperature: float = 1.0,
           top_k: int = 0, top_p: float = 1.0) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32."""
    if temperature == 0.0:
        return greedy(logits)
    logits = logits / temperature
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
