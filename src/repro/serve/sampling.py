"""Token sampling: greedy, temperature, top-k, nucleus."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Rows with temperature below this decode greedily. The per-row path
# clamps the softmax denominator to the same constant, so the greedy
# fallback must trigger at the same threshold — a row with
# 0 < t < GREEDY_EPS would otherwise sample from the clamped
# near-greedy softmax instead of decoding greedily (discontinuous at
# the boundary, and distinct from the scalar path's behaviour).
GREEDY_EPS = 1e-6


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def filter_logits(logits: jnp.ndarray, *, top_k: int = 0,
                  top_p: float = 1.0) -> jnp.ndarray:
    """Static top-k / nucleus filter over the last axis (any leading
    dims); filtered entries go to -inf. Shared by :func:`sample` and
    the speculative verify acceptance rule, which must score draft
    tokens against exactly the distribution decode would sample from.
    """
    if top_k > 0:
        # clamp to the vocab size: top_k >= V keeps every token (the
        # unclamped static index -top_k was out of bounds and raised)
        k = min(int(top_k), logits.shape[-1])
        if k < logits.shape[-1]:
            kth = jnp.sort(logits, axis=-1)[..., -k][..., None]
            logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return logits


def sample(logits: jnp.ndarray, key, *, temperature=1.0,
           top_k: int = 0, top_p: float = 1.0) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32.

    ``temperature`` may be a python float or a per-row (B,) array —
    continuous batching mixes greedy and sampled requests in one
    lockstep step, and a traced temperature operand keeps that a single
    compiled program. Rows with temperature < ``GREEDY_EPS`` decode
    greedily (from the raw logits, so ``top_k``/``top_p`` never perturb
    a greedy row).
    """
    if jnp.ndim(temperature) == 0 and not isinstance(temperature,
                                                     jax.core.Tracer):
        temperature = float(temperature)     # 0-d np/jnp scalars
    per_row = not isinstance(temperature, (int, float))
    if not per_row:
        if temperature < GREEDY_EPS:
            return greedy(logits)
        logits = logits / temperature
    else:
        # (B,) array or traced scalar: keep one compiled program with
        # the where-based greedy fallback per row
        t = jnp.broadcast_to(jnp.asarray(temperature, logits.dtype),
                             logits.shape[:1])
        raw = logits
        logits = logits / jnp.maximum(t, GREEDY_EPS)[:, None]
    logits = filter_logits(logits, top_k=top_k, top_p=top_p)
    toks = jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
    if per_row:
        return jnp.where(t < GREEDY_EPS, greedy(raw), toks)
    return toks
