"""Host-side radix tree over token prefixes → shared KV pages.

The SGLang idea, scoped to the repo's paged pool: production traffic
concentrates on a handful of system prompts, so most prefill FLOPs and
most live pages recompute identical prefixes. This module remembers,
per *full page* of prompt tokens, which physical page already holds
that page's KV — admission then maps those pages straight into the new
slot's block table (:meth:`PagePool.map_shared`, refcount++) and
chunked prefill replays only the uncached suffix.

Granularity is deliberately page-level, not token-level: a node exists
only for a fully written page (``page_size`` tokens), keyed by the
exact token tuple it holds, so a cached page is byte-reusable as-is.
Within the *last* matched page a partial token-prefix match is still
worth a copy: :meth:`match` reports it as ``(page, keep)`` and the
engine maps it copy-on-write pending — the device copies the ``keep``
kept rows into a private page before the slot's first write
(:meth:`PagePool.cow` + ``lm.cow_copy``).

Eviction is LRU over leaf nodes whose page has no table mapping
(refcount 1 — only the tree's own reference): dropping the node derefs
the page back to the free list. :meth:`evictable` feeds
:meth:`PagePool.available` so admission counts reclaimable pages as
headroom; :meth:`reclaim` must run *outside* pool transactions — a
rollback restores refcounts but cannot resurrect a dropped node, so an
in-transaction eviction would strand the restored count forever.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple


class _Node:
    """One fully-cached prompt page: ``key`` is its exact token tuple
    (length = pool.page_size), ``page`` the physical id the tree holds
    a reference on. Children are keyed by their full token tuple —
    sibling fan-out is tiny in practice (divergent continuations of one
    system prompt), so a dict beats compressed-edge bookkeeping."""

    __slots__ = ("key", "page", "children", "parent", "last_use")

    def __init__(self, key: Tuple[int, ...], page: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.page = page
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.last_use = 0


class PrefixCache:
    """Radix tree of cached prompt pages over a :class:`PagePool`.

    The cache owns one refcount per node page; the pool frees a page
    only when the last table mapping *and* the tree reference are gone.
    Install as ``pool.reclaimer`` so admission headroom includes
    evictable branches.
    """

    def __init__(self, pool):
        self.pool = pool
        self.ps = pool.page_size
        self.root: Dict[Tuple[int, ...], _Node] = {}
        self._clock = 0
        self.evictions = 0

    # -- lookup --------------------------------------------------------

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        while node is not None:
            node.last_use = self._clock
            node = node.parent

    def match(self, tokens) -> Tuple[List[int],
                                     Optional[Tuple[int, int]]]:
        """Walk the tree along ``tokens``: returns ``(pages, partial)``
        where ``pages`` are physical ids covering the longest run of
        fully matched prompt pages and ``partial`` is ``(page, keep)``
        for the deepest child sharing ``keep`` leading tokens of the
        next page (COW material), or None. Matched nodes are
        LRU-touched."""
        toks = [int(t) for t in tokens]
        pages: List[int] = []
        children, node, off = self.root, None, 0
        while off + self.ps <= len(toks):
            child = children.get(tuple(toks[off:off + self.ps]))
            if child is None:
                break
            node, children, off = child, child.children, off + self.ps
            pages.append(child.page)
        if node is not None:
            self._touch(node)
        # partial: deepest child sharing the longest strict token prefix
        # of the next (incomplete or mismatched) page
        rest = toks[off:off + self.ps]
        best, best_keep = None, 0
        for key, child in children.items():
            keep = 0
            for a, b in zip(rest, key):
                if a != b:
                    break
                keep += 1
            if keep > best_keep:
                best, best_keep = child, keep
        if best is not None:
            self._touch(best)
            return pages, (best.page, best_keep)
        return pages, None

    # -- insertion -----------------------------------------------------

    def insert(self, tokens, pages) -> int:
        """Record a slot's written prompt pages: one node per *full*
        page of ``tokens``, adopting the corresponding physical id from
        ``pages`` (the slot's block-table row). New nodes take a tree
        reference (:meth:`PagePool.ref_page`); where a node already
        exists the incumbent page is kept — the newcomer's copy stays
        private to its slot and dies at retire. Returns nodes added."""
        toks = [int(t) for t in tokens]
        children, node, added = self.root, None, 0
        for i in range(len(toks) // self.ps):
            key = tuple(toks[i * self.ps:(i + 1) * self.ps])
            child = children.get(key)
            if child is None:
                page = int(pages[i])
                self.pool.ref_page(page)
                child = _Node(key, page, node)
                children[key] = child
                added += 1
            node, children = child, child.children
        if node is not None:
            self._touch(node)
        return added

    # -- eviction ------------------------------------------------------

    def _leaves(self) -> List[Tuple[Dict, Tuple[int, ...], _Node]]:
        out, stack = [], [(self.root, k, n) for k, n in self.root.items()]
        while stack:
            parent, key, node = stack.pop()
            if node.children:
                stack.extend((node.children, k, n)
                             for k, n in node.children.items())
            else:
                out.append((parent, key, node))
        return out

    def evictable(self) -> int:
        """Pages reclaimable *right now* under cascaded LRU eviction:
        every node whose whole subtree holds only tree references
        (refcount 1). Eviction takes leaves first, so a node with any
        table-mapped descendant is pinned until that mapping retires —
        but a fully unreferenced branch drains end to end within one
        :meth:`reclaim` call, so it counts in full. Counting leaves
        alone would under-report headroom and deadlock an admission
        whose page need exceeds the current leaf fringe. This is what
        :meth:`PagePool.available` adds to the free list."""
        total = 0
        clean: Dict[int, bool] = {}
        stack = [(n, False) for n in self.root.values()]
        while stack:
            node, visited = stack.pop()
            if not visited:               # post-order: children first
                stack.append((node, True))
                stack.extend((c, False)
                             for c in node.children.values())
                continue
            ok = (self.pool.refs[node.page] == 1
                  and all(clean[id(c)]
                          for c in node.children.values()))
            clean[id(node)] = ok
            total += ok
        return total

    def reclaim(self, n: int) -> int:
        """Evict up to ``n`` pages, LRU leaf first, cascading into
        parents as branches empty. Must run outside pool transactions
        (see module docstring). Returns pages actually freed."""
        assert not self.pool.in_transaction(), (
            "prefix-cache eviction inside a pool transaction: rollback "
            "could not restore the dropped node")
        freed = 0
        while freed < n:
            cands = [(node.last_use, parent, key, node)
                     for parent, key, node in self._leaves()
                     if self.pool.refs[node.page] == 1]
            if not cands:
                break
            _, parent, key, node = min(cands, key=lambda c: c[0])
            del parent[key]
            assert self.pool.deref(node.page), (
                "evicted a still-referenced page")
            self.evictions += 1
            freed += 1
        return freed

    def reset(self) -> None:
        """Drop the whole tree, releasing every node's reference (used
        by engine fault recovery, which zeroes device KV — cached pages
        no longer hold the bytes their keys promise)."""
        stack = list(self.root.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            self.pool.deref(node.page)
        self.root = {}
