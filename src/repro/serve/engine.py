"""Serving engine: paged-KV continuous batching with bucketed prefill.

vLLM-style paging adapted to JAX static shapes: a fixed batch of
``n_slots`` sequences decodes in lockstep, but attention KV lives in
per-layer page *pools* shared by every slot — a retiring sequence hands
its pages back to a free list and the refilling request takes only what
its prompt needs, so short sequences never pay ``max_len`` attention
traffic. All host <-> device choreography is compile-stable:

  * decode is ONE jitted program — block tables, lengths, per-slot
    temperatures, the active mask and the fault-injection poison mask
    are traced operands;
  * prefill pads prompts to a static bucket ladder (powers of two up to
    ``max_len``) and fuses the prefill forward, the paged cache insert
    and first-token sampling into one jitted program per bucket, so
    continuous batching over arbitrary prompt lengths compiles at most
    ``n_buckets + 1`` programs (archs with recurrent/MoE state prefill
    at exact lengths — see ``paging.supports_bucketing``);
  * with ``paging.prefill_chunk`` set, prompts longer than the chunk
    *chunk-prefill*: each engine step advances every mid-prefill slot by
    one bounded row panel (``lm.prefill_chunk``), interleaved with the
    decode step; chunk shapes stay on the bucket ladder, so the compile
    count is bounded by ``n_buckets + n_chunk_shapes + 1``;
  * with ``paging.table_width_bucketing`` set, the decode block table is
    sliced to the batch's max live pages rounded up to a power of two,
    so executed gather volume tracks live-page traffic — at the cost of
    up to ``log2(max_pages)`` extra compiled decode programs;
  * the decode loop fetches exactly one device value per step (the
    sampled tokens plus their finite-ness flags, in one transfer);
    sequence lengths are mirrored on the host.

On top of that sits the **request lifecycle and fault-tolerance layer**
(DESIGN.md §7). Every submitted rid is guaranteed exactly one terminal
:class:`Completion` whose ``status`` says how it ended:

  ``ok``       hit its ``max_new`` budget
  ``eos``      sampled the EOS token
  ``length``   hit the engine's ``max_len`` KV cap
  ``deadline`` exceeded its ``Request.deadline_s`` (queued or running)
  ``cancelled`` :meth:`Engine.cancel` was called on it
  ``preempted_requeued``  returned unfinished (``run`` hit ``max_steps``
               or :meth:`Engine.shutdown` drained the engine); carries
               the tokens produced so far and may be resubmitted
  ``failed``   quarantined (NaN/inf logits), unserviceable on this pool,
               or gave up after repeated faults

The machinery behind the guarantee:

  * **Transactional admission** — every multi-page mutation of
    :class:`~repro.serve.paging.PagePool` (admit+ensure, chunk growth,
    decode tail allocation) runs inside ``begin``/``commit``/
    ``rollback``, so an allocation failure mid-admission restores the
    exact prior allocator state instead of leaking half an admission.
  * **Preemption** — when a deadlined queue head is blocked behind
    deadline-free (or laxer) residents, the youngest such slot is
    preempted: its pages roll back to the free list and the request
    re-enqueues *with the tokens it already produced*; re-admission
    replays ``prompt + tokens[:-1]`` through the ordinary (chunked)
    prefill path and greedily re-derives the last token, so the resumed
    greedy stream is bit-identical to the unpreempted one. Pure
    pool-pressure preemption is opt-in via ``preempt_patience``.
  * **Recovery boundary** — the decode cache is donated, so a mid-step
    exception invalidates it; ``run`` catches step/admit/chunk failures,
    rebuilds device state (fresh paged cache, zeroed host mirrors) and
    replays every live request from its host-side record. A request
    that keeps failing retires as ``failed`` instead of looping.
  * **NaN quarantine** — the decode step computes per-slot finite-ness
    of the logits *inside the jit* (fetched with the sampled tokens in
    the same transfer); a poisoned slot retires as ``failed`` instead of
    corrupting the lockstep batch. With no poisoning the guard is
    bitwise inert.
  * **Fault injection** — a seeded :class:`~repro.serve.faults.FaultPlan`
    drives all of the above deterministically, keyed on ``Engine.clock``
    (one tick per run-loop iteration, monotonic across ``run`` calls).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.types import ModelConfig, PagingConfig
from repro.models import lm
from repro.serve import sampling, spec
from repro.serve.faults import AllocFault, FaultPlan, StepFault
from repro.serve.placement import CACHE, PARAMS, REP, SingleDevice
from repro.serve.paging import (PagePool, bucket_for, chunk_schedule,
                                default_buckets, page_aligned_size,
                                spec_ladder, supports_bucketing)
from repro.serve.prefix_cache import PrefixCache

TERMINAL_STATUSES = ("ok", "eos", "length", "deadline", "cancelled",
                     "preempted_requeued", "failed")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32 — host-resident.
    #                                  submit() accepts a jnp array and
    #                                  normalises it to numpy ONCE at
    #                                  the host boundary; admission and
    #                                  resume then slice it sync-free
    #                                  (the auditor's RWA103 caught the
    #                                  old per-admission np.asarray on
    #                                  a device prompt: a hidden
    #                                  device->host transfer every time
    #                                  a blocked queue head retried)
    max_new: int = 32
    temperature: Optional[float] = None   # None => engine default
    deadline_s: Optional[float] = None    # seconds after submission by
    #                                  which the request must finish;
    #                                  None => no deadline

@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prompt_len: int
    latency_s: float                 # submission -> retirement
    ttft_s: float = 0.0              # submission -> first token (queue
    #                                  wait + prefill, the serving TTFT)
    queue_s: float = 0.0             # submission -> first admission: the
    #                                  queue-wait component of ttft_s,
    #                                  split out so a bench can attribute
    #                                  a prefix-cache hit's TTFT win to
    #                                  skipped compute rather than a
    #                                  shorter queue (never-admitted
    #                                  requests report their full latency)
    itl_s: List[float] = dataclasses.field(default_factory=list)
    #                                  inter-token gaps (len(tokens) - 1
    #                                  entries): the stall a co-resident
    #                                  prefill admission injects shows up
    #                                  here as a latency spike
    status: str = "ok"               # terminal status, one of
    #                                  TERMINAL_STATUSES


@dataclasses.dataclass
class _Pending:
    """A queued unit of work: a fresh request, or a preempted/recovered
    one carrying the tokens it already produced. Re-admission replays
    ``prompt + prior[:-1]`` through the ordinary prefill path and the
    prefill sample re-derives ``prior[-1]`` (bit-identical under
    greedy), so resume needs no special device machinery."""
    req: Request
    t0: float                        # submission wall time (TTFT base)
    prior: List[int] = dataclasses.field(default_factory=list)
    prior_times: List[float] = dataclasses.field(default_factory=list)
    ttft: Optional[float] = None     # preserved across preemption: the
    #                                  first token was already delivered
    admit_t: Optional[float] = None  # first admission wall time (queue_s
    #                                  base), preserved across preemption
    finished: bool = False           # exactly-once terminal guard


@dataclasses.dataclass
class _ChunkState:
    """Per-slot chunked-prefill progress (host side)."""
    pend: _Pending
    prompt: np.ndarray               # (S,) int32 effective prompt
    sched: List[tuple]               # remaining (offset, len, shape)
    #                                  panels (paging.chunk_schedule)
    hit: int = 0                     # prompt tokens served by shared
    #                                  prefix-cache pages (sched covers
    #                                  only positions >= hit)
    cow: bool = False                # the page at hit // page_size is a
    #                                  COW-pending shared page: remap it
    #                                  before the first chunk writes in


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 512, eos_id: int = 1,
                 temperature: float = 0.0, top_k: int = 0,
                 top_p: float = 1.0, seed: int = 0,
                 paging: PagingConfig = PagingConfig(),
                 buckets: Optional[List[int]] = None,
                 cache_dtype=None, placement=None,
                 faults: Optional[FaultPlan] = None,
                 preempt_patience: Optional[int] = None,
                 max_recoveries: int = 8, max_rid_failures: int = 3):
        self.placement = placement or SingleDevice()
        # fail at construction, never mid-step: an indivisible mesh axis
        # would otherwise surface as an XLA shape crash deep in a jit
        self.placement.validate(cfg)
        self.cfg = cfg
        # the config the jitted model code traces against: per-shard
        # heads/d_ff under tensor parallelism, cfg itself on one device
        rcfg = self.placement.compute_cfg(cfg)
        self.n_slots, self.max_len, self.eos_id = n_slots, max_len, eos_id
        self.temperature = temperature
        # engine-level static top-k / nucleus filter: traced nowhere, so
        # the decode/verify programs stay one compile each; greedy rows
        # (per-row temperature < GREEDY_EPS) sample from the raw logits
        # and are bit-identical with and without the filter
        self.top_k, self.top_p = int(top_k), float(top_p)
        self.key = jax.random.PRNGKey(seed)

        ps = page_aligned_size(paging.page_size, cfg)
        self.page_size = ps
        self.max_pages = -(-max_len // ps)
        self._n_pages = paging.n_pages or n_slots * self.max_pages
        self.pool = PagePool(self._n_pages, ps, n_slots, self.max_pages)
        self._twb = paging.table_width_bucketing
        # KV-cache dtype: explicit override > the embed leaf's dtype >
        # cfg.dtype. A weight-only int8 tree (quant.quantize_tree) stores
        # the embed leaf as a {"q","s"} dict, which jnp.result_type used
        # to crash on — quantized trees fall back to the config dtype.
        if cache_dtype is not None:
            dtype = jnp.dtype(cache_dtype)
        elif quant.is_quantized(params["embed"]):
            dtype = jnp.dtype(cfg.dtype)
        else:
            dtype = jnp.result_type(params["embed"])
        self.cache_dtype = dtype
        # placement owns where params and pools live (sharded under TP)
        self.params = self.placement.prepare_params(params, cfg)
        self.cache = self.placement.prepare_cache(self._init_cache())
        if buckets is not None:
            if not supports_bucketing(cfg):
                raise ValueError(
                    f"{cfg.name} carries recurrent/MoE prefill state: "
                    "padded buckets are inexact, prompts must prefill at "
                    "exact lengths (omit `buckets`)")
            self.buckets: Optional[List[int]] = sorted(buckets)
            if self.buckets[-1] < max_len:
                raise ValueError(
                    f"largest bucket {self.buckets[-1]} must cover "
                    f"max_len={max_len} (every admissible prompt length)")
        elif supports_bucketing(cfg):
            self.buckets = default_buckets(max_len, paging.min_bucket)
        else:
            self.buckets = None      # exact-length prefill (recurrent/MoE)

        self.prefill_chunk = paging.prefill_chunk
        if self.prefill_chunk:
            if self.buckets is None:
                raise ValueError(
                    f"{cfg.name} carries recurrent/MoE prefill state: a "
                    "prompt cannot be split across chunk forwards "
                    "(chunked prefill needs pure causal-attention KV)")
            if self.prefill_chunk not in self.buckets:
                raise ValueError(
                    f"prefill_chunk={self.prefill_chunk} must sit on the "
                    f"bucket ladder {self.buckets} (chunk shapes reuse "
                    "the ladder to bound the compile count)")

        # radix-tree prefix cache (PR 8): admission maps fully shared
        # prompt pages into the new slot's table (zero prefill FLOPs)
        # and chunked prefill replays only the uncached suffix.
        # Sliding-window archs are silently excluded — the one per-slot
        # block table is shared across layers, and a ring write through
        # a shared page would clobber every other mapper's cached
        # prefix — as are bucketing-incapable archs (no chunk path).
        self.prefix_cache: Optional[PrefixCache] = None
        self.prefill_token_budget = paging.prefill_token_budget
        windowed = any(blk.mixer == "attn" and blk.window
                       for stage in cfg.stages() for blk in stage.body)
        if paging.prefix_cache and self.buckets is not None \
                and not windowed:
            if not self.prefill_chunk:
                raise ValueError(
                    "prefix_cache requires prefill_chunk: cache hits "
                    "prefill only the uncached suffix through the chunk "
                    "program (suffix shapes stay on the bucket ladder, "
                    "keeping the compile bound)")
            self.prefix_cache = PrefixCache(self.pool)
            self.pool.reclaimer = self.prefix_cache

        # self-speculative decode (DESIGN.md §10): a host-side
        # prompt-lookup drafter proposes up to spec_k tokens per slot and
        # a batched verify step scores the whole panel through the chunk
        # kernels, amortising decode's per-step weight stream over every
        # accepted token. Panel widths pad up the documented spec ladder
        # so the verify program compiles len(ladder) times, no more.
        self.spec_k = paging.speculate_k
        self.spec_ladder = spec_ladder(self.spec_k)
        if self.spec_k:
            if self.buckets is None:
                raise ValueError(
                    f"{cfg.name} carries recurrent/MoE prefill state: a "
                    "verify panel cannot score draft tokens in one "
                    "forward (speculation needs pure causal-attention "
                    "KV, like chunked prefill)")
            if self._twb:
                raise ValueError(
                    "speculate_k is mutually exclusive with "
                    "table_width_bucketing: the decode width ladder "
                    "would multiply the spec k-ladder in the compile "
                    "bound — speculative steps ship full-width tables")

        # recurring jit operands are committed through the placement so
        # their sharding signature never flips host->mesh mid-run
        put = self.placement.put_rep
        self.lengths = put(jnp.zeros((n_slots,), jnp.int32))
        self._host_len = np.zeros((n_slots,), np.int64)
        self._last = put(jnp.zeros((n_slots, 1), jnp.int32))
        self._temps = put(jnp.zeros((n_slots,), jnp.float32))
        self._tables_dev = put(jnp.asarray(self.pool.tables))
        self._tables_key = (self.pool.version, frozenset(), self.max_pages)
        self.active: List[Optional[_Pending]] = [None] * n_slots
        self.chunking: Dict[int, _ChunkState] = {}   # slot -> progress
        self.out_tokens: List[List[int]] = [[] for _ in range(n_slots)]
        self.started = [0.0] * n_slots
        self.ttft = [0.0] * n_slots
        self._token_times: List[List[float]] = [[] for _ in range(n_slots)]
        self.queue: deque = deque()          # of _Pending
        self._prefill_lens: set = set()   # distinct padded lengths seen
        self._chunk_shapes: set = set()   # distinct chunk panel shapes
        self._step_widths: set = set()    # distinct decode table widths
        self._spec_shapes: set = set()    # distinct verify panel widths
        self._stepped = False
        self.completed: List[Completion] = []
        self.kv_trace: List[List[int]] = []   # per-step live slot lengths

        # lifecycle / fault-tolerance state
        self.faults = faults if faults is not None else FaultPlan()
        self.clock = 0               # run-loop tick, monotonic across runs
        self.preempt_patience = preempt_patience
        self.max_recoveries = max_recoveries
        self.max_rid_failures = max_rid_failures
        self.stats = {"preemptions": 0, "recoveries": 0,
                      "recompute_tokens": 0, "nan_quarantined": 0,
                      "alloc_faults": 0,
                      # prefix-cache counters (PR 8)
                      "prefix_hits": 0, "prefix_hit_tokens": 0,
                      "prompt_tokens": 0, "cow_copies": 0,
                      "cow_in_place": 0, "share_deferrals": 0,
                      # token-budgeted chunk scheduling
                      "budget_deferred_chunks": 0,
                      # self-speculative decoding (PR 10): steps that
                      # carried drafts, tokens drafted, tokens accepted
                      "spec_steps": 0, "spec_slot_steps": 0,
                      "spec_drafted": 0, "spec_accepted": 0}
        self.page_trace: List[tuple] = []   # per-step (unique, mapped)
        self._share_deferred = False
        self.errors: List[str] = []  # reprs of recovered exceptions
        self._terminal: set = set()  # rids with a terminal completion
        self._fail_counts: Dict[int, int] = {}   # rid -> recovery replays
        self._admit_seq = [0] * n_slots          # admission order (age)
        self._seq = 0
        self._head_blocked = 0       # consecutive iters the head waited

        tk, tp = self.top_k, self.top_p    # static: closed over, one jit

        def step_fn(params, cache, tokens, lengths, tables, temps, active,
                    poison, key):
            logits, cache = lm.decode_step(params, cache, tokens, lengths,
                                           rcfg, pages=tables)
            # fault injection + containment, both traced so the program
            # count stays 1: `poison` overwrites a slot's logits with
            # NaN (chaos testing the guard below); `bad` flags any
            # non-finite row so the host can quarantine it. With poison
            # all-False and finite logits both `where`s are identity —
            # the guarded step is bitwise identical to the unguarded one.
            logits = jnp.where(poison[:, None], jnp.nan, logits)
            bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
            safe = jnp.where(bad[:, None], 0.0, logits)
            nxt = sampling.sample(safe, key, temperature=temps,
                                  top_k=tk, top_p=tp)
            # idle / mid-prefill slots stay parked at length 0 writing
            # their private scratch page
            new_lengths = jnp.where(active, lengths + 1, 0)
            return nxt, bad, new_lengths, cache

        def admit_fn(params, cache, lengths, last, tokens, slot, pages_row,
                     plen, temp, key):
            logits, states = lm.prefill_states(params, tokens, rcfg,
                                               last_pos=plen[None])
            cache = lm.insert_prefill(rcfg, cache, states, slot=slot,
                                      pages=pages_row, plen=plen,
                                      page_size=ps)
            bad = ~jnp.all(jnp.isfinite(logits))
            safe = jnp.where(bad, 0.0, logits)
            first = sampling.sample(safe, key, temperature=temp[None],
                                    top_k=tk, top_p=tp)[0]
            lengths = lengths.at[slot].set(plen)
            last = last.at[slot, 0].set(first)
            return first, bad, cache, lengths, last

        def chunk_fn(params, cache, tokens, offset, chunk_len, slot,
                     pages_row, lengths, last, temp, key, cow_src,
                     cow_dst):
            # copy-on-write seam, folded into the chunk program: before
            # the first chunk that writes into a partially-shared
            # prefix page, the host remaps the table row and passes the
            # (src, dst) physical ids here; every other chunk passes
            # (0, 0) — an identity self-copy — so ONE compiled program
            # serves both and the non-COW path stays bitwise identical
            cache = lm.cow_copy(cache, cow_src, cow_dst)
            logits, cache = lm.prefill_chunk(params, cache, tokens, rcfg,
                                             offset=offset,
                                             chunk_len=chunk_len,
                                             pages=pages_row[None])
            # a NaN written by an *earlier* chunk propagates through the
            # prefix-page attention into these logits, so checking the
            # final chunk's flag covers the whole chunked prefill
            bad = ~jnp.all(jnp.isfinite(logits))
            safe = jnp.where(bad, 0.0, logits)
            tok = sampling.sample(safe, key, temperature=temp[None],
                                  top_k=tk, top_p=tp)[0]
            # one program per chunk shape: every call samples and books
            # the slot's length, but the host only *fetches* the token
            # (and flips the slot active) on the final chunk — until
            # then decode keeps the slot masked out and re-zeroes these
            lengths = lengths.at[slot].set(offset + chunk_len)
            last = last.at[slot, 0].set(tok)
            return tok, bad, cache, lengths, last

        def spec_fn(params, cache, tokens, lengths, tables, temps, active,
                    poison, draft_len, key):
            # Speculative verify (DESIGN.md §10): `tokens` is a
            # (B, 1 + k_pad) panel — the last committed token followed by
            # each slot's padded draft. One chunk-style forward scores
            # every position against the paged prefix WITHOUT writing
            # pages; acceptance runs in the same jit and only the
            # accepted prefix is inserted, so a rejected draft never
            # touches the pool (exact for sliding-window rings, which a
            # write-then-undo could not be).
            b, sc = tokens.shape
            kpad = sc - 1
            # inactive slots score a width-1 panel at offset 0 (the
            # scratch-page decode equivalent); a width-0 row would leave
            # both attention partials fully masked
            clen = jnp.where(active, 1 + draft_len, 1)
            logits, states = lm.verify_states(
                params, cache, tokens, rcfg, offset=lengths,
                chunk_len=clen, pages=tables)
            logits = jnp.where(poison[:, None, None], jnp.nan, logits)
            rows = jnp.arange(sc)[None, :]
            finite = jnp.all(jnp.isfinite(logits), axis=-1)
            bad = ~jnp.all(finite | (rows >= clen[:, None]), axis=-1)
            safe = jnp.where(bad[:, None, None], 0.0, logits)
            t = jnp.broadcast_to(jnp.asarray(temps, safe.dtype), (b,))
            greedy_row = t < sampling.GREEDY_EPS
            # the exact distribution decode would sample position i from
            filt = sampling.filter_logits(
                safe / jnp.maximum(t, sampling.GREEDY_EPS)[:, None, None],
                top_k=tk, top_p=tp)
            probs = jax.nn.softmax(filt, axis=-1)
            draft = tokens[:, 1:]
            p_draft = jnp.take_along_axis(
                probs[:, :kpad], draft[..., None], axis=-1)[..., 0]
            akey, skey = jax.random.split(key)
            u = jax.random.uniform(akey, (b, kpad))
            amax = jnp.argmax(safe, axis=-1).astype(jnp.int32)
            # standard rejection rule with a deterministic drafter
            # (q = 1 on the proposed token): accept d_i with prob
            # p_i(d_i); greedy rows accept exactly the argmax chain.
            # n_acc = longest accepted prefix (cumprod-sum).
            acc = jnp.where(greedy_row[:, None],
                            draft == amax[:, :kpad], u < p_draft)
            acc &= jnp.arange(kpad)[None, :] < draft_len[:, None]
            n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1),
                            axis=1)
            # the step's own emitted token comes from position n_acc: on
            # rejection the drafted token is masked out and the leftover
            # mass renormalised (with the acceptance test this keeps
            # decode's distribution exact); on a full accept it is the
            # bonus token scored for free by the panel's last row
            idx = n_acc[:, None, None]
            v = safe.shape[-1]
            raw_at = jnp.take_along_axis(
                safe, jnp.broadcast_to(idx, (b, 1, v)), axis=1)[:, 0]
            f_at = jnp.take_along_axis(
                filt, jnp.broadcast_to(idx, (b, 1, v)), axis=1)[:, 0]
            rej = n_acc < draft_len
            d_rej = jnp.take_along_axis(
                draft, jnp.minimum(n_acc, kpad - 1)[:, None],
                axis=1)[:, 0]
            masked = (rej & ~greedy_row)[:, None] \
                & (jnp.arange(v)[None, :] == d_rej[:, None])
            f_at = jnp.where(masked, -jnp.inf, f_at)
            toks = jax.random.categorical(skey, f_at,
                                          axis=-1).astype(jnp.int32)
            nxt = jnp.where(greedy_row,
                            jnp.argmax(raw_at, axis=-1).astype(jnp.int32),
                            toks)
            # write ONLY the committed token plus the accepted prefix
            n_keep = jnp.where(active, 1 + n_acc, 0)
            cache = lm.insert_verify(rcfg, cache, states, pages=tables,
                                     offset=lengths, n_keep=n_keep)
            n_acc = jnp.where(active, n_acc, 0).astype(jnp.int32)
            new_lengths = jnp.where(active, lengths + 1 + n_acc, 0)
            return nxt, n_acc, bad, new_lengths, cache

        # donate the cache: the pool update aliases in place instead of
        # copying the whole (R, n_pages + n_slots, ps, Hkv, hd) pools
        # every step. Placement owns the jit: under TP the entry points
        # run in shard_map over the mesh, host operands replicated.
        self._step = self.placement.jit(
            step_fn, kinds=(PARAMS, CACHE) + (REP,) * 7,
            out_kinds=(REP, REP, REP, CACHE), donate=(1,))
        self._admit = self.placement.jit(
            admit_fn, kinds=(PARAMS, CACHE) + (REP,) * 8,
            out_kinds=(REP, REP, CACHE, REP, REP), donate=(1,))
        self._chunk = self.placement.jit(
            chunk_fn, kinds=(PARAMS, CACHE) + (REP,) * 11,
            out_kinds=(REP, REP, CACHE, REP, REP), donate=(1,))
        # verify shards exactly like chunk prefill: replicated panel in,
        # head-sharded pool gather/insert, replicated tokens/counts out
        self._spec = self.placement.jit(
            spec_fn, kinds=(PARAMS, CACHE) + (REP,) * 8,
            out_kinds=(REP, REP, REP, REP, CACHE), donate=(1,))

    # ------------------------------------------------------------------

    def _init_cache(self):
        return lm.init_paged_cache(self.cfg, self.n_slots, self.max_len,
                                   page_size=self.page_size,
                                   n_pages=self._n_pages,
                                   dtype=self.cache_dtype)

    def submit(self, req: Request):
        if not isinstance(req.prompt, np.ndarray):
            # the one sanctioned device->host transfer for a prompt:
            # once per submission, never per admission attempt
            req = dataclasses.replace(
                req, prompt=np.asarray(req.prompt, np.int32))
        plen = int(req.prompt.shape[0])
        if not 0 < plen <= self.max_len:
            raise ValueError(f"prompt of length {plen} cannot decode "
                             f"within max_len={self.max_len}")
        if req.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {req.max_new} "
                             "(every request produces the prefill token)")
        if plen == self.max_len and req.max_new > 1:
            # prefill-only request: admission writes exactly max_len KV
            # rows and the prefill-sampled token retires it — there is
            # no in-bounds cache row left for a decode step to write
            req = dataclasses.replace(req, max_new=1)
        self.queue.append(_Pending(req=req, t0=time.perf_counter()))

    def compile_counts(self) -> dict:
        """Compiled-program counts of the serving entry points — jax's
        jit cache size when available (ground truth), else the host-side
        proxy (distinct padded prefill lengths / chunk panel shapes /
        decode table widths / verify panel widths map 1:1 to compiled
        programs). The ``spec`` entry appears only when speculation is
        configured — a spec-free engine keeps the PR 3 three-key shape
        its consumers already compare against."""
        def n(fn, fallback):
            return fn._cache_size() if hasattr(fn, "_cache_size") \
                else fallback
        counts = {"prefill": n(self._admit, len(self._prefill_lens)),
                  "chunk": n(self._chunk, len(self._chunk_shapes)),
                  "step": n(self._step, len(self._step_widths))}
        if self.spec_k:
            counts["spec"] = n(self._spec, len(self._spec_shapes))
        return counts

    def audit_entry_points(self):
        """The jitted entry points with representative arguments,
        shaped exactly as the run loop passes them — for the static
        auditor (repro.analysis), which lowers and traces these without
        executing anything. Each entry is ``(name, fn, args,
        donate_argnums)``; the donated cache is only annotated by
        ``lower``/``make_jaxpr``, never consumed."""
        key = jax.random.PRNGKey(0)
        row = jnp.asarray(self.pool.tables[0])
        off = np.zeros((self.n_slots,), bool)
        entries = [
            ("step", self._step,
             (self.params, self.cache, self._last, self.lengths,
              self._tables_dev, self._temps, jnp.asarray(off),
              jnp.asarray(off), key), (1,)),
        ]
        bl = self.buckets[0] if self.buckets else min(8, self.max_len)
        entries.append(
            ("prefill", self._admit,
             (self.params, self.cache, self.lengths, self._last,
              jnp.zeros((1, bl), jnp.int32), jnp.int32(0), row,
              jnp.int32(bl), jnp.float32(self.temperature), key), (1,)))
        if self.prefill_chunk:
            c = self.prefill_chunk
            entries.append(
                ("chunk", self._chunk,
                 (self.params, self.cache, jnp.zeros((1, c), jnp.int32),
                  jnp.int32(0), jnp.int32(c), jnp.int32(0), row,
                  self.lengths, self._last,
                  jnp.float32(self.temperature), key,
                  jnp.int32(0), jnp.int32(0)), (1,)))
        if self.spec_k:
            w = 1 + self.spec_ladder[0]
            entries.append(
                ("spec", self._spec,
                 (self.params, self.cache,
                  jnp.zeros((self.n_slots, w), jnp.int32), self.lengths,
                  self._tables_dev, self._temps, jnp.asarray(off),
                  jnp.asarray(off),
                  jnp.zeros((self.n_slots,), jnp.int32), key), (1,)))
        return entries

    def _req_temp(self, req: Request) -> float:
        return self.temperature if req.temperature is None else \
            req.temperature

    # -- lifecycle ------------------------------------------------------

    def _finish(self, pend: _Pending, tokens: List[int], status: str, *,
                ttft: float = 0.0, itl: Optional[List[float]] = None):
        """The single exit point: every accepted unit of work passes
        through here exactly once, whatever ended it."""
        assert status in TERMINAL_STATUSES, status
        assert not pend.finished, \
            f"rid {pend.req.rid} reached a second terminal completion"
        pend.finished = True
        self._terminal.add(pend.req.rid)
        now = time.perf_counter()
        self.completed.append(Completion(
            rid=pend.req.rid, tokens=tokens,
            prompt_len=int(pend.req.prompt.shape[0]),
            latency_s=now - pend.t0,
            ttft_s=ttft if ttft else (pend.ttft or 0.0),
            queue_s=(pend.admit_t - pend.t0
                     if pend.admit_t is not None else now - pend.t0),
            itl_s=itl if itl is not None else
            [b - a for a, b in zip(pend.prior_times,
                                   pend.prior_times[1:])],
            status=status))

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it is (queued, mid-prefill, or
        decoding); returns False if the rid is unknown or already
        terminal. The completion carries any tokens already produced."""
        for slot, pend in enumerate(self.active):
            if pend is not None and pend.req.rid == rid:
                self._retire(slot, "cancelled")
                return True
        for slot, st in list(self.chunking.items()):
            if st.pend.req.rid == rid:
                del self.chunking[slot]
                self.pool.release(slot)
                self._finish(st.pend, list(st.pend.prior), "cancelled")
                return True
        for pend in list(self.queue):
            if pend.req.rid == rid:
                self.queue.remove(pend)
                self._finish(pend, list(pend.prior), "cancelled")
                return True
        return False

    def shutdown(self) -> List[Completion]:
        """Drain the engine: every outstanding rid gets a terminal
        ``preempted_requeued`` completion carrying its tokens so far
        (resubmittable), and the engine returns to a clean, fully
        serviceable state."""
        self._flush_outstanding("preempted_requeued")
        return self.completed

    def _flush_outstanding(self, status: str):
        """Terminal-complete every live slot and queued entry (slots in
        admission order, then queue order), releasing all pool pages."""
        live = sorted((s for s in range(self.n_slots)
                       if self.active[s] is not None or s in self.chunking),
                      key=lambda s: self._admit_seq[s])
        for slot in live:
            if self.active[slot] is not None:
                self._retire(slot, status)
            else:
                st = self.chunking.pop(slot)
                self.pool.release(slot)
                self._finish(st.pend, list(st.pend.prior), status)
        while self.queue:
            pend = self.queue.popleft()
            self._finish(pend, list(pend.prior), status)

    def _sweep_deadlines(self):
        now = time.perf_counter()

        def over(p: _Pending) -> bool:
            return (p.req.deadline_s is not None
                    and now - p.t0 > p.req.deadline_s)

        for slot in range(self.n_slots):
            pend = self.active[slot]
            if pend is not None and over(pend):
                self._retire(slot, "deadline")
        for slot in list(self.chunking):
            st = self.chunking[slot]
            if over(st.pend):
                del self.chunking[slot]
                self.pool.release(slot)
                self._finish(st.pend, list(st.pend.prior), "deadline")
        if any(over(p) for p in self.queue):
            keep: deque = deque()
            for pend in self.queue:
                if over(pend):
                    self._finish(pend, list(pend.prior), "deadline")
                else:
                    keep.append(pend)
            self.queue = keep

    # -- preemption -----------------------------------------------------

    def _pend_at(self, slot: int) -> _Pending:
        return self.active[slot] if self.active[slot] is not None \
            else self.chunking[slot].pend

    def _preempt_slot(self, slot: int):
        """Evict a live slot: pages back to the free list, the request
        back onto the queue (behind the blocked head) carrying its
        produced tokens for bit-identical greedy resume."""
        if self.active[slot] is not None:
            pend = self.active[slot]
            new = _Pending(req=pend.req, t0=pend.t0,
                           prior=list(self.out_tokens[slot]),
                           prior_times=list(self._token_times[slot]),
                           ttft=self.ttft[slot], admit_t=pend.admit_t)
            self.active[slot] = None
            self.out_tokens[slot] = []
            self._token_times[slot] = []
            self._host_len[slot] = 0
        else:
            # chunked prefill in flight: its pages roll back and the
            # prompt replays from the top (no tokens produced yet)
            new = self.chunking.pop(slot).pend
        self.pool.release(slot)
        self.stats["preemptions"] += 1
        self.stats["recompute_tokens"] += (int(new.req.prompt.shape[0])
                                           + max(len(new.prior) - 1, 0))
        if self.queue:
            self.queue.insert(1, new)    # behind the blocked head
        else:
            self.queue.appendleft(new)

    def _maybe_preempt(self) -> bool:
        """Called when the queue head could not admit this iteration.
        Deadline inversion (a deadlined head starved by deadline-free or
        laxer residents) always preempts; pure pool pressure preempts
        only after `preempt_patience` consecutive blocked iterations."""
        if not self.queue:
            return False
        live = [s for s in range(self.n_slots)
                if self.active[s] is not None or s in self.chunking]
        if not live:
            return False
        head = self.queue[0]
        if head.req.deadline_s is not None:
            def abs_dl(p: _Pending) -> float:
                return (p.t0 + p.req.deadline_s
                        if p.req.deadline_s is not None else float("inf"))
            cands = [s for s in live if abs_dl(self._pend_at(s))
                     > abs_dl(head)]
            if cands:
                self._preempt_slot(max(cands,
                                       key=lambda s: self._admit_seq[s]))
                return True
        if (self.preempt_patience is not None
                and self._head_blocked >= self.preempt_patience):
            self._head_blocked = 0
            self._preempt_slot(max(live,
                                   key=lambda s: self._admit_seq[s]))
            return True
        return False

    # -- admission ------------------------------------------------------

    def _effective_prompt(self, pend: _Pending) -> np.ndarray:
        """The token rows admission must (re)compute: the prompt, plus —
        when resuming a preempted/recovered request — every produced
        token but the last, whose KV row was never written (the prefill
        sample re-derives it)."""
        p = np.asarray(pend.req.prompt, np.int32)
        if pend.prior:
            p = np.concatenate(
                [p, np.asarray(pend.prior[:-1], np.int32)])
        return p

    def _worst_case(self, pend: _Pending) -> int:
        # KV rows ever written: the prompt plus one row per decode step
        # (the final sampled token is returned, never written). Resume
        # preserves it: prior tokens move rows from the decode side to
        # the prompt side without changing the sum.
        plen = int(pend.req.prompt.shape[0])
        return min(self.max_len, plen + pend.req.max_new - 1)

    def _make_room(self, draws: int):
        """Evict LRU prefix-cache branches until the free list covers
        the ``draws`` page draws the caller is about to make. Must run
        BEFORE the transaction bracketing the draws: a rollback
        restores refcounts but cannot resurrect a dropped tree node, so
        an in-transaction eviction would strand the page forever."""
        if self.prefix_cache is not None and draws > len(self.pool.free):
            self.prefix_cache.reclaim(draws - len(self.pool.free))

    def _prefix_match(self, prompt: np.ndarray):
        """Walk the prefix cache for an admission candidate: returns
        ``(shared_pages, partial, hit_tokens)`` — physical ids covering
        fully-cached prompt pages, an optional ``(page, keep)`` COW
        candidate for the next partially-shared page, and the total
        cached token count. The hit is capped at ``plen - 1`` so at
        least one suffix token remains: its chunk forward produces the
        prompt's first-token logits (a fully-cached page-aligned prompt
        demotes its last full page to a COW partial)."""
        if self.prefix_cache is None:
            return [], None, 0
        plen = int(prompt.shape[0])
        pages, partial = self.prefix_cache.match(prompt)
        ps = self.page_size
        cap = plen - 1
        if len(pages) * ps > cap:
            partial = (pages[-1], cap - (len(pages) - 1) * ps)
            pages = pages[:-1]
        keep = partial[1] if partial is not None else 0
        keep = min(keep, cap - len(pages) * ps)
        partial = (partial[0], keep) if partial is not None and keep > 0 \
            else None
        hit = len(pages) * ps + (partial[1] if partial else 0)
        return pages, partial, hit

    def _share_defer(self, prompt: np.ndarray, hit: int) -> bool:
        """Duplicate-prefix admission race (two near-identical prompts
        in flight): True when some mid-prefill slot is computing a
        longer shared prefix than the tree serves today — by the time
        that provider activates (inserting its pages), re-matching maps
        them for free instead of recomputing them into private pages."""
        if self.prefix_cache is None:
            return False
        plen = int(prompt.shape[0])
        best = 0
        for st in self.chunking.values():
            m = min(plen, int(st.prompt.shape[0]))
            diff = np.flatnonzero(prompt[:m] != st.prompt[:m])
            n = int(diff[0]) if diff.size else m
            best = max(best, (n // self.page_size) * self.page_size)
        return min(best, plen - 1) > hit

    def _fill_slots(self) -> int:
        # heads that could NEVER admit retire as failed instead of
        # wedging the FIFO forever (the pool simply cannot hold them)
        while self.queue:
            pend = self.queue[0]
            if (self.pool._pages_for(self._worst_case(pend))
                    <= self.pool.n_pages):
                break
            self.queue.popleft()
            self._finish(pend, list(pend.prior), "failed")
        admitted = 0
        self._share_deferred = False
        for slot in range(self.n_slots):
            if (self.active[slot] is not None or slot in self.chunking
                    or not self.queue):
                continue
            pend = self.queue[0]
            req = pend.req
            worst = self._worst_case(pend)
            prompt = self._effective_prompt(pend)
            plen = int(prompt.shape[0])
            shared, partial, hit = self._prefix_match(prompt)
            if self._share_defer(prompt, hit):
                # an in-flight chunked prefill is building a longer
                # shared prefix than the tree holds today: admitting now
                # would recompute its pages into private copies — wait
                # for the provider instead. run() does not count this
                # as a blocked head, so the provider is never preempted
                # to "unblock" the head it is about to serve.
                self._share_deferred = True
                self.stats["share_deferrals"] += 1
                break
            if not self.pool.can_admit_pages(
                    self.pool._pages_for(worst)
                    + (1 if partial is not None else 0)):
                break                # FIFO: wait for pages, don't skip
            self.stats["prompt_tokens"] += plen
            if hit:
                # prefix-cache hit: map the shared pages (refcount++,
                # zero prefill FLOPs for those rows) and schedule only
                # the uncached suffix through the chunk path; a
                # partially-covered boundary page maps COW-pending (its
                # private replacement is the +1 page charged above)
                self.pool.begin()
                self.pool.admit(slot, worst)
                self.pool.map_shared(slot, shared)
                if partial is not None:
                    self.pool.map_shared(slot, [partial[0]],
                                         cow_tail=True)
                self.pool.commit()
                self.queue.popleft()
                if pend.admit_t is None:
                    pend.admit_t = time.perf_counter()
                self._seq += 1
                self._admit_seq[slot] = self._seq
                admitted += 1
                self.stats["prefix_hits"] += 1
                self.stats["prefix_hit_tokens"] += hit
                self.chunking[slot] = _ChunkState(
                    pend=pend, prompt=prompt,
                    sched=[(hit + o, c, s) for o, c, s in
                           chunk_schedule(plen - hit, self.prefill_chunk,
                                          self.buckets)],
                    hit=hit, cow=partial is not None)
                continue
            if not (self.prefill_chunk and plen > self.prefill_chunk):
                # one-shot prefill draws the whole prompt inside the
                # transaction below — evict LRU branches first (never
                # inside: rollback can't resurrect a dropped node)
                self._make_room(self.pool._pages_for(plen))
            self.pool.begin()
            try:
                self.pool.admit(slot, worst)
                if self.prefill_chunk and plen > self.prefill_chunk:
                    # chunked prefill: reserve now, run the prompt as
                    # row panels across engine steps (_advance_chunks) —
                    # pages are charged per chunk, and admission itself
                    # costs no forward, so co-resident decode slots
                    # never stall on the monolithic bucket program
                    self.pool.commit()
                    self.queue.popleft()
                    if pend.admit_t is None:
                        pend.admit_t = time.perf_counter()
                    self._seq += 1
                    self._admit_seq[slot] = self._seq
                    admitted += 1
                    self.chunking[slot] = _ChunkState(
                        pend=pend, prompt=prompt,
                        sched=chunk_schedule(plen, self.prefill_chunk,
                                             self.buckets))
                    continue
                self.pool.ensure(slot, plen)
            except AllocFault:
                self.pool.rollback()
                self.stats["alloc_faults"] += 1
                break                # retry the same head next iteration
            self.pool.commit()
            self.queue.popleft()
            if pend.admit_t is None:
                pend.admit_t = time.perf_counter()
            self._seq += 1
            self._admit_seq[slot] = self._seq
            admitted += 1
            bl = bucket_for(plen, self.buckets) if self.buckets else plen
            self._prefill_lens.add(bl)
            padded = np.zeros((1, bl), np.int32)
            padded[0, :plen] = prompt
            self.key, sk = jax.random.split(self.key)
            try:
                first, bad, self.cache, self.lengths, self._last = \
                    self._admit(
                        self.params, self.cache, self.lengths, self._last,
                        jnp.asarray(padded), jnp.int32(slot),
                        jnp.asarray(self.pool.tables[slot]),
                        jnp.int32(plen),
                        jnp.float32(self._req_temp(req)), sk)
                first, bad = jax.device_get((first, bad))
            except Exception:
                # the admit program itself died: restore the pool and
                # the queue head before the recovery boundary takes over,
                # so the rid is never lost and no pages leak
                self.pool.release(slot)
                self.queue.appendleft(pend)
                raise
            if bad:
                # non-finite prefill logits: quarantine before the slot
                # ever joins the lockstep batch
                self.pool.release(slot)
                self.stats["nan_quarantined"] += 1
                self._finish(pend, list(pend.prior), "failed")
                continue
            self._activate(slot, pend, int(first))
        return admitted

    def _activate(self, slot, pend: _Pending, first: int):
        """A slot's prefill (one-shot or final chunk) produced its first
        token: move it to decode, book TTFT, retire if already done. On
        resume, `first` re-derives the last pre-preemption token and the
        earlier ones are restored from the host-side record."""
        req = pend.req
        if self.prefix_cache is not None:
            # adopt the slot's freshly written full prompt pages into
            # the radix tree (shared prefixes keep their incumbent
            # node). Decode never writes them: the decode write lands
            # at page plen_eff // ps, past every *full* prompt page.
            prompt = self._effective_prompt(pend)
            if int(prompt.shape[0]) >= self.page_size:
                self.prefix_cache.insert(prompt, self.pool.tables[slot])
        self._temps = self._temps.at[slot].set(self._req_temp(req))
        self.active[slot] = pend
        self.out_tokens[slot] = list(pend.prior[:-1]) + [first]
        self.started[slot] = pend.t0
        now = time.perf_counter()
        if pend.ttft is None:
            pend.ttft = now - pend.t0
        self.ttft[slot] = pend.ttft
        self._token_times[slot] = list(pend.prior_times[:-1]) + [now]
        self._host_len[slot] = (int(req.prompt.shape[0])
                                + max(len(pend.prior) - 1, 0))
        # the prefill-sampled token can already finish the request
        if first == self.eos_id:
            self._retire(slot, "eos")
        elif len(self.out_tokens[slot]) >= req.max_new:
            self._retire(slot, "ok")

    def _advance_chunks(self) -> int:
        """Advance mid-prefill slots by one bounded row panel each,
        oldest admission first, under the optional Sarathi-style
        per-step prefill token budget (``paging.prefill_token_budget``:
        padded chunk tokens per step; the oldest slot always advances,
        so prefill can't fully starve — the budget trades prefill
        throughput for decode cadence when cache-miss suffixes of mixed
        lengths pile up). Returns the number of chunks processed."""
        advanced = 0
        spent = 0
        budget = self.prefill_token_budget
        for slot in sorted(self.chunking,
                           key=lambda s: self._admit_seq[s]):
            st = self.chunking[slot]
            off, clen, shape = st.sched[0]
            if budget and advanced and spent + shape > budget:
                self.stats["budget_deferred_chunks"] += 1
                continue
            draws = max(0, self.pool._pages_for(off + clen)
                        - int(self.pool.n_alloc[slot]))
            if st.cow:
                draws += 1           # worst case: the COW private copy
            self._make_room(draws)
            cow_src = cow_dst = 0
            self.pool.begin()
            try:
                self.pool.ensure(slot, off + clen)   # charged per chunk
                if st.cow:
                    # first suffix chunk always writes into the
                    # partially-shared boundary page (off == hit lands
                    # mid-page): remap it before the scatter
                    src, dst = self.pool.cow(slot,
                                             st.hit // self.page_size)
                    if src != dst:
                        cow_src, cow_dst = src, dst
                        self.stats["cow_copies"] += 1
                    else:
                        self.stats["cow_in_place"] += 1
            except AllocFault:
                self.pool.rollback()
                self.stats["alloc_faults"] += 1
                continue             # same panel (and COW) retries next
            self.pool.commit()
            st.cow = False
            if self.prefix_cache is not None:
                for lp in range(off // self.page_size,
                                (off + clen - 1) // self.page_size + 1):
                    pg = int(self.pool.tables[slot, lp])
                    assert self.pool.refs[pg] == 1, (
                        f"chunk would scatter into shared page {pg}")
            self._chunk_shapes.add(shape)
            padded = np.zeros((1, shape), np.int32)
            padded[0, :clen] = st.prompt[off:off + clen]
            self.key, sk = jax.random.split(self.key)
            tok, bad, self.cache, self.lengths, self._last = self._chunk(
                self.params, self.cache, jnp.asarray(padded),
                jnp.int32(off), jnp.int32(clen), jnp.int32(slot),
                jnp.asarray(self.pool.tables[slot]),
                self.lengths, self._last,
                jnp.float32(self._req_temp(st.pend.req)), sk,
                jnp.int32(cow_src), jnp.int32(cow_dst))
            spent += shape
            st.sched.pop(0)
            advanced += 1
            if not st.sched:
                # final chunk: the ONLY chunk whose outputs the host
                # fetches — intermediate chunks stay fully async (a NaN
                # they wrote reaches this chunk's logits via the prefix
                # gather, so one flag covers the whole prefill)
                tok, bad = jax.device_get((tok, bad))
                del self.chunking[slot]
                if bad:
                    self.pool.release(slot)
                    self.stats["nan_quarantined"] += 1
                    self._finish(st.pend, list(st.pend.prior), "failed")
                else:
                    self._activate(slot, st.pend, int(tok))
        return advanced

    def _retire(self, slot, status: str):
        pend = self.active[slot]
        times = self._token_times[slot]
        self._finish(pend, list(self.out_tokens[slot]), status,
                     ttft=self.ttft[slot],
                     itl=[b - a for a, b in zip(times, times[1:])])
        self.pool.release(slot)
        self.active[slot] = None
        self.out_tokens[slot] = []
        self._token_times[slot] = []
        self._host_len[slot] = 0

    # -- speculation ----------------------------------------------------

    def _draft_budget(self, slot: int) -> int:
        """Max draft length worth proposing for a slot: the engine k-cap,
        the request's remaining ``max_new`` budget (a fully accepted
        draft emits k+1 tokens this step) and the KV cap (the verify
        step writes up to 1+k rows, and the ``max_len`` length
        retirement must keep firing on the final row exactly as plain
        decode would)."""
        pend = self.active[slot]
        return min(self.spec_k,
                   pend.req.max_new - len(self.out_tokens[slot]) - 1,
                   self.max_len - int(self._host_len[slot]) - 2)

    def _build_drafts(self, active):
        """Host side of a speculative step: run the prompt-lookup
        drafter per active slot and pack the (B, 1 + k_pad) verify
        panel — row 0 is the slot's last committed token (the host
        mirror of ``_last``), then its draft, padded up the documented
        spec ladder; true per-slot lengths travel in the traced
        ``draft_len`` operand. Returns ``(panel, draft_len)`` numpy
        arrays, or None when nothing drafted (plain decode step)."""
        if not self.spec_k:
            return None
        props = {}
        for slot in np.flatnonzero(active):
            slot = int(slot)
            k = self._draft_budget(slot)
            if k <= 0:
                continue
            pend = self.active[slot]
            hist = np.concatenate(
                [np.asarray(pend.req.prompt, np.int32),
                 np.asarray(self.out_tokens[slot], np.int32)])
            d = spec.propose(hist, k)
            if d.size:
                props[slot] = d
        if not props:
            return None
        kpad = bucket_for(max(len(d) for d in props.values()),
                          self.spec_ladder)
        panel = np.zeros((self.n_slots, 1 + kpad), np.int32)
        dlen = np.zeros((self.n_slots,), np.int32)
        for slot in np.flatnonzero(active):
            panel[int(slot), 0] = self.out_tokens[int(slot)][-1]
        for slot, d in props.items():
            panel[slot, 1:1 + len(d)] = d
            dlen[slot] = len(d)
        return panel, dlen

    # -- device mirrors -------------------------------------------------

    def _table_width(self) -> int:
        """Decode block-table width: `max_pages`, or — under table-width
        bucketing — the batch max live pages rounded up to a power of
        two, so the per-step gather reads what's live, not the worst
        case. Safe for windowed rings: a slot's allocation always covers
        its length, so the ring never wraps earlier than it would at
        full width."""
        if not self._twb:
            return self.max_pages
        hi = int(self.pool.n_alloc.max(initial=0))
        width = 1 if hi <= 1 else 1 << (hi - 1).bit_length()
        return min(width, self.max_pages)

    def _ship_tables(self):
        """Mirror the block tables to the device when they changed.
        Mid-prefill slots' rows are masked to their scratch page: the
        lockstep decode step still writes a row for every slot, and the
        real table already names live pages the next chunk will fill —
        without the mask the decode write would land in them."""
        width = self._table_width()
        key = (self.pool.version, frozenset(self.chunking), width)
        if key == self._tables_key:
            return
        tables = self.pool.tables[:, :width]
        if self.chunking:
            tables = tables.copy()
            for s in self.chunking:
                tables[s, :] = self.pool.scratch[s]
        self._tables_dev = self.placement.put_rep(jnp.asarray(tables))
        self._tables_key = key

    # -- fault machinery ------------------------------------------------

    def _arm_alloc_fault(self, clock: int):
        """One-shot: the first page draw this iteration raises; later
        draws (and iterations) succeed, so forward progress resumes."""
        fired = []

        def hook():
            if not fired:
                fired.append(True)
                raise AllocFault(
                    f"injected allocation failure @clock {clock}")
        self.pool.alloc_hook = hook

    def _recover(self):
        """Recovery boundary: a step/admit/chunk raised, so the donated
        cache (and any in-flight device state) is presumed lost. Rebuild
        device state from scratch and replay every live request from its
        host-side record — queued at the FRONT in admission order, so
        recompute happens before new work. A rid that keeps tripping the
        boundary retires as `failed` instead of looping forever."""
        while self.pool.in_transaction():
            self.pool.rollback()
        self.cache = self.placement.prepare_cache(self._init_cache())
        put = self.placement.put_rep
        self.lengths = put(jnp.zeros((self.n_slots,), jnp.int32))
        self._last = put(jnp.zeros((self.n_slots, 1), jnp.int32))
        self._temps = put(jnp.zeros((self.n_slots,), jnp.float32))
        live = sorted((s for s in range(self.n_slots)
                       if self.active[s] is not None or s in self.chunking),
                      key=lambda s: self._admit_seq[s])
        for slot in reversed(live):      # appendleft keeps admission order
            if self.active[slot] is not None:
                pend = self.active[slot]
                new = _Pending(req=pend.req, t0=pend.t0,
                               prior=list(self.out_tokens[slot]),
                               prior_times=list(self._token_times[slot]),
                               ttft=self.ttft[slot],
                               admit_t=pend.admit_t)
                self.active[slot] = None
                self.out_tokens[slot] = []
                self._token_times[slot] = []
                self._host_len[slot] = 0
            else:
                new = self.chunking.pop(slot).pend
            self.pool.release(slot)
            rid = new.req.rid
            self._fail_counts[rid] = self._fail_counts.get(rid, 0) + 1
            if self._fail_counts[rid] > self.max_rid_failures:
                self._finish(new, list(new.prior), "failed")
            else:
                self.stats["recompute_tokens"] += (
                    int(new.req.prompt.shape[0])
                    + max(len(new.prior) - 1, 0))
                self.queue.appendleft(new)
        if self.prefix_cache is not None:
            # the rebuilt device cache is zeroed: cached pages no longer
            # hold the bytes their keys promise, so the tree drops too
            self.prefix_cache.reset()
        self._tables_key = None      # force a reship

    # -- the loop -------------------------------------------------------

    def run(self, max_steps: int = 10_000) -> List[Completion]:
        """Continuous-batching loop until queue + slots drain. One
        iteration = deadline sweep + admissions (preempting if a
        deadlined head is starved) + one chunk per mid-prefill slot +
        one lockstep decode step. Hitting `max_steps` does NOT drop
        work: everything outstanding terminal-completes as
        `preempted_requeued` (tokens so far attached) and the engine
        stays serviceable."""
        steps = 0
        recoveries = 0
        self.kv_trace = []           # fresh trace per run (bounded host mem)
        self.page_trace = []         # per-step (unique physical, mapped)
        while (any(a is not None for a in self.active) or self.queue
               or self.chunking):
            if steps >= max_steps:
                self._flush_outstanding("preempted_requeued")
                break
            steps += 1
            clock = self.clock
            self.clock += 1
            if self.faults.alloc_fails(clock):
                self._arm_alloc_fault(clock)
            slow = self.faults.slow_s(clock)
            if slow:
                time.sleep(slow)
            try:
                self._sweep_deadlines()
                admitted = self._fill_slots()
                if self.queue and admitted == 0:
                    # a share-deferred head is *waiting on* a resident
                    # prefill, not starved by it: counting it as blocked
                    # could preempt the very slot about to serve it
                    if not self._share_deferred:
                        self._head_blocked += 1
                        if self._maybe_preempt():
                            admitted += self._fill_slots()
                else:
                    self._head_blocked = 0
                self._advance_chunks()
                self.page_trace.append((self.pool.unique_live(),
                                        self.pool.live_pages()))
                active = np.asarray([a is not None for a in self.active])
                if not active.any():
                    if self.queue or self.chunking:
                        continue     # blocked or mid-prefill: next tick
                    break            # everything admitted retired at once
                drafts = self._build_drafts(active)
                dlen = (drafts[1] if drafts is not None
                        else np.zeros((self.n_slots,), np.int32))
                # rows this step may write: the decode position, plus —
                # speculating — the slot's full draft tail (rejected
                # tail pages roll back after the accepted counts land)
                need = {int(s): int(self._host_len[s]) + 1 + int(dlen[s])
                        for s in np.flatnonzero(active)}
                self._make_room(sum(
                    max(0, self.pool._pages_for(n)
                        - int(self.pool.n_alloc[s]))
                    for s, n in need.items()))
                self.pool.begin()
                try:
                    for s, n in need.items():
                        self.pool.ensure(s, n)      # lazy tail draws
                except AllocFault:
                    self.pool.rollback()
                    self.stats["alloc_faults"] += 1
                    continue         # whole step retries next iteration
                self.pool.commit()
                if self.prefix_cache is not None:
                    for s, n in need.items():
                        for lp in range(
                                int(self._host_len[s]) // self.page_size,
                                (n - 1) // self.page_size + 1):
                            pg = int(self.pool.tables[s, lp])
                            assert self.pool.refs[pg] == 1, (
                                f"decode write aimed at shared page {pg}")
                self._ship_tables()
                poison = np.zeros((self.n_slots,), bool)
                pslots = self.faults.poison_slots(clock)
                if pslots:
                    for s in pslots:
                        if s is None:
                            poison |= active
                        else:
                            poison[s] = True
                if self.faults.step_raises(clock):
                    raise StepFault(
                        f"injected step exception @clock {clock}")
                self.key, sk = jax.random.split(self.key)
                if drafts is not None:
                    self._spec_shapes.add(int(drafts[0].shape[1]))
                    nxt, n_acc, bad, self.lengths, self.cache = \
                        self._spec(
                            self.params, self.cache,
                            jnp.asarray(drafts[0]), self.lengths,
                            self._tables_dev, self._temps,
                            jnp.asarray(active), jnp.asarray(poison),
                            jnp.asarray(dlen), sk)
                    fetch = (nxt, bad, n_acc)
                else:
                    nxt, bad, self.lengths, self.cache = self._step(
                        self.params, self.cache, self._last,
                        self.lengths, self._tables_dev, self._temps,
                        jnp.asarray(active), jnp.asarray(poison), sk)
                    self._step_widths.add(int(self._tables_dev.shape[1]))
                    fetch = (nxt, bad)
                self._last = nxt[:, None]
                self._stepped = True
                # the step's ONE device fetch (tokens + NaN flags — and,
                # on a speculative step, per-slot accepted counts — in
                # one transfer)
                got = jax.device_get(fetch)
                nxt_host, bad_host = got[0], got[1]
                acc_host = (np.asarray(got[2], np.int64) if len(got) > 2
                            else np.zeros((self.n_slots,), np.int64))
                now = time.perf_counter()
                if drafts is not None:
                    self.stats["spec_steps"] += 1
                    self.stats["spec_slot_steps"] += int(active.sum())
                    self.stats["spec_drafted"] += int(dlen[active].sum())
                    self.stats["spec_accepted"] += int(
                        acc_host[active].sum())
                self._host_len[active] += 1 + acc_host[active]
                self._host_len[~active] = 0
                self.kv_trace.append(
                    [int(self._host_len[s])
                     for s in np.flatnonzero(active)])
                for slot in np.flatnonzero(active):
                    slot = int(slot)
                    pend = self.active[slot]
                    if bad_host[slot]:
                        # quarantine: this slot's logits went non-finite;
                        # retire it alone, the lockstep batch moves on
                        self.stats["nan_quarantined"] += 1
                        self._retire(slot, "failed")
                        continue
                    emitted = [int(nxt_host[slot])]
                    if drafts is not None:
                        # accepted draft prefix first, then the verify
                        # step's own replacement/bonus token
                        emitted = [int(t) for t in drafts[0][
                            slot, 1:1 + int(acc_host[slot])]] + emitted
                    for tok in emitted:
                        self.out_tokens[slot].append(tok)
                        self._token_times[slot].append(now)
                        if tok == self.eos_id:
                            self._retire(slot, "eos")
                            break
                        if len(self.out_tokens[slot]) >= \
                                pend.req.max_new:
                            self._retire(slot, "ok")
                            break
                    if self.active[slot] is None:
                        continue     # retired mid-emission: pages freed
                    if int(self._host_len[slot]) >= self.max_len - 1:
                        self._retire(slot, "length")
                    elif drafts is not None:
                        # return the rejected draft tail's pages; the
                        # reservation survives (rollback_tail is legal
                        # outside a pool transaction)
                        self.pool.rollback_tail(
                            slot, int(self._host_len[slot]))
            except Exception as err:
                # recovery boundary: injected StepFault or a real device
                # error mid-step — the donated cache is presumed lost.
                # (AllocFault is handled transactionally at its draw
                # sites above and never reaches here.)
                self.errors.append(repr(err))
                self.stats["recoveries"] += 1
                recoveries += 1
                if recoveries > self.max_recoveries:
                    self._flush_outstanding("failed")
                    break
                self._recover()
            finally:
                self.pool.alloc_hook = None
        return self.completed
