"""Serving engine: batched prefill/decode with continuous batching.

vLLM-style slot management adapted to JAX static shapes: a fixed batch of
`n_slots` sequences decodes in lockstep; when a sequence finishes, its
slot is refilled from the request queue by (a) running a single-request
prefill and (b) scattering the prefilled KV into the batched cache at
that slot index. All jitted steps have static shapes, so continuous
batching never recompiles.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig
from repro.models import lm
from repro.serve import sampling


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray              # (S,) int32
    max_new: int = 32


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prompt_len: int
    latency_s: float


def _scatter_slot(cache, slot_cache, slot: int, prefill_len: int):
    """Insert a single-request prefilled cache into batch slot `slot`."""
    def ins(dst, src):
        if dst.ndim >= 3 and src.shape[0] == dst.shape[0]:
            # (R, B, ...) leaves: write batch index `slot`
            if src.ndim == dst.ndim and src.shape[1] == 1:
                if dst.ndim >= 4 and src.shape[2] <= dst.shape[2]:
                    pad = [(0, 0)] * src.ndim
                    pad[2] = (0, dst.shape[2] - src.shape[2])
                    src = jnp.pad(src, pad)
                return jax.lax.dynamic_update_slice(
                    dst, src.astype(dst.dtype),
                    (0, slot) + (0,) * (dst.ndim - 2))
        return dst
    return jax.tree.map(ins, cache, slot_cache)


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 512, eos_id: int = 1,
                 temperature: float = 0.0, seed: int = 0):
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len, self.eos_id = n_slots, max_len, eos_id
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.cache = lm.init_cache(cfg, n_slots, max_len)
        self.lengths = jnp.zeros((n_slots,), jnp.int32)
        self.active = [None] * n_slots           # Request or None
        self.out_tokens: List[List[int]] = [[] for _ in range(n_slots)]
        self.started = [0.0] * n_slots
        self.queue: deque = deque()
        self.completed: List[Completion] = []
        self._last = jnp.zeros((n_slots, 1), jnp.int32)

        def step_fn(params, cache, tokens, lengths, key):
            logits, cache = lm.decode_step(params, cache, tokens, lengths,
                                           cfg)
            if temperature == 0.0:
                nxt = sampling.greedy(logits)
            else:
                nxt = sampling.sample(logits, key,
                                      temperature=temperature)
            return nxt, cache

        self._step = jax.jit(step_fn)
        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, t, cfg, alloc=max_len))

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slots(self):
        for slot in range(self.n_slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                t0 = time.perf_counter()
                logits, pcache = self._prefill(self.params,
                                               req.prompt[None])
                plen = int(req.prompt.shape[0])
                self.cache = _scatter_slot(self.cache, pcache, slot, plen)
                first = int(jnp.argmax(logits[0]))
                self.active[slot] = req
                self.out_tokens[slot] = [first]
                self.started[slot] = t0
                self.lengths = self.lengths.at[slot].set(plen)
                self._last = self._last.at[slot, 0].set(first)

    def _retire(self, slot):
        req = self.active[slot]
        self.completed.append(Completion(
            rid=req.rid, tokens=list(self.out_tokens[slot]),
            prompt_len=int(req.prompt.shape[0]),
            latency_s=time.perf_counter() - self.started[slot]))
        self.active[slot] = None
        self.out_tokens[slot] = []

    def run(self, max_steps: int = 10_000) -> List[Completion]:
        """Continuous-batching loop until queue + slots drain."""
        steps = 0
        while (any(a is not None for a in self.active) or self.queue):
            self._fill_slots()
            if not any(a is not None for a in self.active):
                break
            self.key, sk = jax.random.split(self.key)
            nxt, self.cache = self._step(self.params, self.cache,
                                         self._last, self.lengths, sk)
            self.lengths = self.lengths + 1
            self._last = nxt[:, None]
            for slot in range(self.n_slots):
                req = self.active[slot]
                if req is None:
                    continue
                tok = int(nxt[slot])
                self.out_tokens[slot].append(tok)
                done = (tok == self.eos_id
                        or len(self.out_tokens[slot]) >= req.max_new
                        or int(self.lengths[slot]) >= self.max_len - 1)
                if done:
                    self._retire(slot)
            steps += 1
            if steps >= max_steps:
                break
        return self.completed
