"""Serving engine: paged-KV continuous batching with bucketed prefill.

vLLM-style paging adapted to JAX static shapes: a fixed batch of
``n_slots`` sequences decodes in lockstep, but attention KV lives in
per-layer page *pools* shared by every slot — a retiring sequence hands
its pages back to a free list and the refilling request takes only what
its prompt needs, so short sequences never pay ``max_len`` attention
traffic. All host <-> device choreography is compile-stable:

  * decode is ONE jitted program — block tables, lengths, per-slot
    temperatures and the active mask are traced operands;
  * prefill pads prompts to a static bucket ladder (powers of two up to
    ``max_len``) and fuses the prefill forward, the paged cache insert
    and first-token sampling into one jitted program per bucket, so
    continuous batching over arbitrary prompt lengths compiles at most
    ``n_buckets + 1`` programs (archs with recurrent/MoE state prefill
    at exact lengths — see ``paging.supports_bucketing``);
  * with ``paging.prefill_chunk`` set, prompts longer than the chunk
    *chunk-prefill*: each engine step advances every mid-prefill slot by
    one bounded row panel (``lm.prefill_chunk`` — prefix-page attention
    + positioned KV append), interleaved with the decode step, so the
    largest bucket's monolithic program never stalls co-resident decode
    slots (the TTFT cliff). Only the final chunk's sampled token is
    fetched; chunk shapes stay on the bucket ladder, so the compile
    count is bounded by ``n_buckets + n_chunk_shapes + 1``;
  * the decode loop fetches exactly one device value per step (the
    sampled tokens); sequence lengths are mirrored on the host.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.types import ModelConfig, PagingConfig
from repro.models import lm
from repro.serve import sampling
from repro.serve.placement import CACHE, PARAMS, REP, SingleDevice
from repro.serve.paging import (PagePool, bucket_for, chunk_schedule,
                                default_buckets, page_aligned_size,
                                supports_bucketing)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray              # (S,) int32
    max_new: int = 32
    temperature: Optional[float] = None   # None => engine default


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prompt_len: int
    latency_s: float                 # submission -> retirement
    ttft_s: float = 0.0              # submission -> first token (queue
    #                                  wait + prefill, the serving TTFT)
    itl_s: List[float] = dataclasses.field(default_factory=list)
    #                                  inter-token gaps (len(tokens) - 1
    #                                  entries): the stall a co-resident
    #                                  prefill admission injects shows up
    #                                  here as a latency spike


@dataclasses.dataclass
class _ChunkState:
    """Per-slot chunked-prefill progress (host side)."""
    req: Request
    t0: float                        # submission wall time (TTFT base)
    prompt: np.ndarray               # (S,) int32 host copy
    sched: List[tuple]               # remaining (offset, len, shape)
    #                                  panels (paging.chunk_schedule)


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 512, eos_id: int = 1,
                 temperature: float = 0.0, seed: int = 0,
                 paging: PagingConfig = PagingConfig(),
                 buckets: Optional[List[int]] = None,
                 cache_dtype=None, placement=None):
        self.placement = placement or SingleDevice()
        # fail at construction, never mid-step: an indivisible mesh axis
        # would otherwise surface as an XLA shape crash deep in a jit
        self.placement.validate(cfg)
        self.cfg = cfg
        # the config the jitted model code traces against: per-shard
        # heads/d_ff under tensor parallelism, cfg itself on one device
        rcfg = self.placement.compute_cfg(cfg)
        self.n_slots, self.max_len, self.eos_id = n_slots, max_len, eos_id
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        ps = page_aligned_size(paging.page_size, cfg)
        self.page_size = ps
        self.max_pages = -(-max_len // ps)
        n_pages = paging.n_pages or n_slots * self.max_pages
        self.pool = PagePool(n_pages, ps, n_slots, self.max_pages)
        # KV-cache dtype: explicit override > the embed leaf's dtype >
        # cfg.dtype. A weight-only int8 tree (quant.quantize_tree) stores
        # the embed leaf as a {"q","s"} dict, which jnp.result_type used
        # to crash on — quantized trees fall back to the config dtype.
        if cache_dtype is not None:
            dtype = jnp.dtype(cache_dtype)
        elif quant.is_quantized(params["embed"]):
            dtype = jnp.dtype(cfg.dtype)
        else:
            dtype = jnp.result_type(params["embed"])
        self.cache_dtype = dtype
        # placement owns where params and pools live (sharded under TP)
        self.params = self.placement.prepare_params(params, cfg)
        self.cache = self.placement.prepare_cache(
            lm.init_paged_cache(cfg, n_slots, max_len, page_size=ps,
                                n_pages=n_pages, dtype=dtype))
        if buckets is not None:
            if not supports_bucketing(cfg):
                raise ValueError(
                    f"{cfg.name} carries recurrent/MoE prefill state: "
                    "padded buckets are inexact, prompts must prefill at "
                    "exact lengths (omit `buckets`)")
            self.buckets: Optional[List[int]] = sorted(buckets)
            if self.buckets[-1] < max_len:
                raise ValueError(
                    f"largest bucket {self.buckets[-1]} must cover "
                    f"max_len={max_len} (every admissible prompt length)")
        elif supports_bucketing(cfg):
            self.buckets = default_buckets(max_len, paging.min_bucket)
        else:
            self.buckets = None      # exact-length prefill (recurrent/MoE)

        self.prefill_chunk = paging.prefill_chunk
        if self.prefill_chunk:
            if self.buckets is None:
                raise ValueError(
                    f"{cfg.name} carries recurrent/MoE prefill state: a "
                    "prompt cannot be split across chunk forwards "
                    "(chunked prefill needs pure causal-attention KV)")
            if self.prefill_chunk not in self.buckets:
                raise ValueError(
                    f"prefill_chunk={self.prefill_chunk} must sit on the "
                    f"bucket ladder {self.buckets} (chunk shapes reuse "
                    "the ladder to bound the compile count)")

        # recurring jit operands are committed through the placement so
        # their sharding signature never flips host->mesh mid-run
        put = self.placement.put_rep
        self.lengths = put(jnp.zeros((n_slots,), jnp.int32))
        self._host_len = np.zeros((n_slots,), np.int64)
        self._last = put(jnp.zeros((n_slots, 1), jnp.int32))
        self._temps = put(jnp.zeros((n_slots,), jnp.float32))
        self._tables_dev = put(jnp.asarray(self.pool.tables))
        self._tables_key = (self.pool.version, frozenset())
        self.active: List[Optional[Request]] = [None] * n_slots
        self.chunking: Dict[int, _ChunkState] = {}   # slot -> progress
        self.out_tokens: List[List[int]] = [[] for _ in range(n_slots)]
        self.started = [0.0] * n_slots
        self.ttft = [0.0] * n_slots
        self._token_times: List[List[float]] = [[] for _ in range(n_slots)]
        self.queue: deque = deque()  # (Request, submission wall time)
        self._prefill_lens: set = set()   # distinct padded lengths seen
        self._chunk_shapes: set = set()   # distinct chunk panel shapes
        self._stepped = False
        self.completed: List[Completion] = []
        self.kv_trace: List[List[int]] = []   # per-step live slot lengths

        def step_fn(params, cache, tokens, lengths, tables, temps, active,
                    key):
            logits, cache = lm.decode_step(params, cache, tokens, lengths,
                                           rcfg, pages=tables)
            nxt = sampling.sample(logits, key, temperature=temps)
            # idle / mid-prefill slots stay parked at length 0 writing
            # their private scratch page
            new_lengths = jnp.where(active, lengths + 1, 0)
            return nxt, new_lengths, cache

        def admit_fn(params, cache, lengths, last, tokens, slot, pages_row,
                     plen, temp, key):
            logits, states = lm.prefill_states(params, tokens, rcfg,
                                               last_pos=plen[None])
            cache = lm.insert_prefill(rcfg, cache, states, slot=slot,
                                      pages=pages_row, plen=plen,
                                      page_size=ps)
            first = sampling.sample(logits, key, temperature=temp[None])[0]
            lengths = lengths.at[slot].set(plen)
            last = last.at[slot, 0].set(first)
            return first, cache, lengths, last

        def chunk_fn(params, cache, tokens, offset, chunk_len, slot,
                     pages_row, lengths, last, temp, key):
            logits, cache = lm.prefill_chunk(params, cache, tokens, rcfg,
                                             offset=offset,
                                             chunk_len=chunk_len,
                                             pages=pages_row[None])
            tok = sampling.sample(logits, key, temperature=temp[None])[0]
            # one program per chunk shape: every call samples and books
            # the slot's length, but the host only *fetches* the token
            # (and flips the slot active) on the final chunk — until
            # then decode keeps the slot masked out and re-zeroes these
            lengths = lengths.at[slot].set(offset + chunk_len)
            last = last.at[slot, 0].set(tok)
            return tok, cache, lengths, last

        # donate the cache: the pool update aliases in place instead of
        # copying the whole (R, n_pages + n_slots, ps, Hkv, hd) pools
        # every step. Placement owns the jit: under TP the entry points
        # run in shard_map over the mesh, host operands replicated.
        self._step = self.placement.jit(
            step_fn, kinds=(PARAMS, CACHE) + (REP,) * 6,
            out_kinds=(REP, REP, CACHE), donate=(1,))
        self._admit = self.placement.jit(
            admit_fn, kinds=(PARAMS, CACHE) + (REP,) * 8,
            out_kinds=(REP, CACHE, REP, REP), donate=(1,))
        self._chunk = self.placement.jit(
            chunk_fn, kinds=(PARAMS, CACHE) + (REP,) * 9,
            out_kinds=(REP, CACHE, REP, REP), donate=(1,))

    # ------------------------------------------------------------------

    def submit(self, req: Request):
        plen = int(req.prompt.shape[0])
        if not 0 < plen <= self.max_len:
            raise ValueError(f"prompt of length {plen} cannot decode "
                             f"within max_len={self.max_len}")
        if req.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {req.max_new} "
                             "(every request produces the prefill token)")
        if plen == self.max_len and req.max_new > 1:
            # prefill-only request: admission writes exactly max_len KV
            # rows and the prefill-sampled token retires it — there is
            # no in-bounds cache row left for a decode step to write
            req = dataclasses.replace(req, max_new=1)
        self.queue.append((req, time.perf_counter()))

    def compile_counts(self) -> dict:
        """Compiled-program counts of the three serving entry points —
        jax's jit cache size when available (ground truth), else the
        host-side proxy (distinct padded prefill lengths / chunk panel
        shapes map 1:1 to compiled programs; one decode program once any
        step ran)."""
        def n(fn, fallback):
            return fn._cache_size() if hasattr(fn, "_cache_size") \
                else fallback
        return {"prefill": n(self._admit, len(self._prefill_lens)),
                "chunk": n(self._chunk, len(self._chunk_shapes)),
                "step": n(self._step, int(self._stepped))}

    def _req_temp(self, req: Request) -> float:
        return self.temperature if req.temperature is None else \
            req.temperature

    def _fill_slots(self) -> int:
        admitted = 0
        for slot in range(self.n_slots):
            if (self.active[slot] is not None or slot in self.chunking
                    or not self.queue):
                continue
            req, t0 = self.queue[0]   # t0: submission time (TTFT base)
            plen = int(req.prompt.shape[0])
            # KV rows ever written: the prompt plus one row per decode
            # step (the final sampled token is returned, never written)
            worst = min(self.max_len, plen + req.max_new - 1)
            if not self.pool.can_admit(worst):
                break                # FIFO: wait for pages, don't skip
            self.queue.popleft()
            admitted += 1
            self.pool.admit(slot, worst)
            if self.prefill_chunk and plen > self.prefill_chunk:
                # chunked prefill: reserve now, run the prompt as row
                # panels across engine steps (_advance_chunks) — pages
                # are charged per chunk, and admission itself costs no
                # forward, so co-resident decode slots never stall on
                # the monolithic largest-bucket program
                self.chunking[slot] = _ChunkState(
                    req=req, t0=t0, prompt=np.asarray(req.prompt),
                    sched=chunk_schedule(plen, self.prefill_chunk,
                                         self.buckets))
                continue
            self.pool.ensure(slot, plen)
            bl = bucket_for(plen, self.buckets) if self.buckets else plen
            self._prefill_lens.add(bl)
            padded = np.zeros((1, bl), np.int32)
            padded[0, :plen] = np.asarray(req.prompt)
            self.key, sk = jax.random.split(self.key)
            first, self.cache, self.lengths, self._last = self._admit(
                self.params, self.cache, self.lengths, self._last,
                jnp.asarray(padded), jnp.int32(slot),
                jnp.asarray(self.pool.tables[slot]), jnp.int32(plen),
                jnp.float32(self._req_temp(req)), sk)
            self._activate(slot, req, t0, int(first))
        return admitted

    def _activate(self, slot, req, t0, first: int):
        """A slot's prefill (one-shot or final chunk) produced its first
        token: move it to decode, book TTFT, retire if already done."""
        self._temps = self._temps.at[slot].set(self._req_temp(req))
        self.active[slot] = req
        self.out_tokens[slot] = [first]
        self.started[slot] = t0
        now = time.perf_counter()
        self.ttft[slot] = now - t0
        self._token_times[slot] = [now]
        self._host_len[slot] = int(req.prompt.shape[0])
        # the prefill-sampled token can already finish the request
        if first == self.eos_id or req.max_new <= 1:
            self._retire(slot)

    def _advance_chunks(self) -> int:
        """Advance every mid-prefill slot by one bounded row panel.
        Returns the number of chunks processed (scheduling progress)."""
        advanced = 0
        for slot in sorted(self.chunking):
            st = self.chunking[slot]
            off, clen, shape = st.sched.pop(0)
            self._chunk_shapes.add(shape)
            self.pool.ensure(slot, off + clen)       # charged per chunk
            padded = np.zeros((1, shape), np.int32)
            padded[0, :clen] = st.prompt[off:off + clen]
            self.key, sk = jax.random.split(self.key)
            tok, self.cache, self.lengths, self._last = self._chunk(
                self.params, self.cache, jnp.asarray(padded),
                jnp.int32(off), jnp.int32(clen), jnp.int32(slot),
                jnp.asarray(self.pool.tables[slot]),
                self.lengths, self._last,
                jnp.float32(self._req_temp(st.req)), sk)
            advanced += 1
            if not st.sched:
                # final chunk: the ONLY chunk whose token the host
                # fetches — intermediate chunks stay fully async
                del self.chunking[slot]
                self._activate(slot, st.req, st.t0, int(tok))
        return advanced

    def _retire(self, slot):
        req = self.active[slot]
        times = self._token_times[slot]
        self.completed.append(Completion(
            rid=req.rid, tokens=list(self.out_tokens[slot]),
            prompt_len=int(req.prompt.shape[0]),
            latency_s=time.perf_counter() - self.started[slot],
            ttft_s=self.ttft[slot],
            itl_s=[b - a for a, b in zip(times, times[1:])]))
        self.pool.release(slot)
        self.active[slot] = None
        self.out_tokens[slot] = []
        self._token_times[slot] = []
        self._host_len[slot] = 0

    def _ship_tables(self):
        """Mirror the block tables to the device when they changed.
        Mid-prefill slots' rows are masked to their scratch page: the
        lockstep decode step still writes a row for every slot, and the
        real table already names live pages the next chunk will fill —
        without the mask the decode write would land in them."""
        key = (self.pool.version, frozenset(self.chunking))
        if key == self._tables_key:
            return
        tables = self.pool.tables
        if self.chunking:
            tables = tables.copy()
            for s in self.chunking:
                tables[s, :] = self.pool.scratch[s]
        self._tables_dev = self.placement.put_rep(jnp.asarray(tables))
        self._tables_key = key

    def run(self, max_steps: int = 10_000) -> List[Completion]:
        """Continuous-batching loop until queue + slots drain. One
        iteration = admissions + one chunk per mid-prefill slot + one
        lockstep decode step."""
        steps = 0
        self.kv_trace = []           # fresh trace per run (bounded host mem)
        while (any(a is not None for a in self.active) or self.queue
               or self.chunking):
            admitted = self._fill_slots()
            chunked = self._advance_chunks()
            active = np.asarray([a is not None for a in self.active])
            if not active.any():
                if self.queue and not admitted and not chunked:
                    raise RuntimeError(
                        "request needs more KV pages than the pool holds "
                        f"({self.pool.n_pages} x {self.page_size} tokens)")
                if self.queue or self.chunking:
                    continue         # everything admitted retired at once
                break
            for slot in np.flatnonzero(active):
                # cover the position this step writes (lazy tail alloc)
                self.pool.ensure(int(slot), int(self._host_len[slot]) + 1)
            self._ship_tables()
            self.key, sk = jax.random.split(self.key)
            nxt, self.lengths, self.cache = self._step(
                self.params, self.cache, self._last, self.lengths,
                self._tables_dev, self._temps, jnp.asarray(active), sk)
            self._last = nxt[:, None]
            self._stepped = True
            nxt_host = jax.device_get(nxt)  # the step's ONE device fetch
            now = time.perf_counter()
            self._host_len[active] += 1
            self._host_len[~active] = 0
            self.kv_trace.append(
                [int(self._host_len[s]) for s in np.flatnonzero(active)])
            for slot in np.flatnonzero(active):
                slot = int(slot)
                req = self.active[slot]
                tok = int(nxt_host[slot])
                self.out_tokens[slot].append(tok)
                self._token_times[slot].append(now)
                done = (tok == self.eos_id
                        or len(self.out_tokens[slot]) >= req.max_new
                        or int(self._host_len[slot]) >= self.max_len - 1)
                if done:
                    self._retire(slot)
            steps += 1
            if steps >= max_steps:
                break
        return self.completed
