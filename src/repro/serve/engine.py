"""Serving engine: paged-KV continuous batching with bucketed prefill.

vLLM-style paging adapted to JAX static shapes: a fixed batch of
``n_slots`` sequences decodes in lockstep, but attention KV lives in
per-layer page *pools* shared by every slot — a retiring sequence hands
its pages back to a free list and the refilling request takes only what
its prompt needs, so short sequences never pay ``max_len`` attention
traffic. All host <-> device choreography is compile-stable:

  * decode is ONE jitted program — block tables, lengths, per-slot
    temperatures and the active mask are traced operands;
  * prefill pads prompts to a static bucket ladder (powers of two up to
    ``max_len``) and fuses the prefill forward, the paged cache insert
    and first-token sampling into one jitted program per bucket, so
    continuous batching over arbitrary prompt lengths compiles at most
    ``n_buckets + 1`` programs (archs with recurrent/MoE state prefill
    at exact lengths — see ``paging.supports_bucketing``);
  * the decode loop fetches exactly one device value per step (the
    sampled tokens); sequence lengths are mirrored on the host.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.types import ModelConfig, PagingConfig
from repro.models import lm
from repro.serve import sampling
from repro.serve.paging import (PagePool, bucket_for, default_buckets,
                                page_aligned_size, supports_bucketing)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray              # (S,) int32
    max_new: int = 32
    temperature: Optional[float] = None   # None => engine default


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prompt_len: int
    latency_s: float                 # submission -> retirement
    ttft_s: float = 0.0              # submission -> first token (queue
    #                                  wait + prefill, the serving TTFT)


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, n_slots: int = 4,
                 max_len: int = 512, eos_id: int = 1,
                 temperature: float = 0.0, seed: int = 0,
                 paging: PagingConfig = PagingConfig(),
                 buckets: Optional[List[int]] = None,
                 cache_dtype=None):
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len, self.eos_id = n_slots, max_len, eos_id
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        ps = page_aligned_size(paging.page_size, cfg)
        self.page_size = ps
        self.max_pages = -(-max_len // ps)
        n_pages = paging.n_pages or n_slots * self.max_pages
        self.pool = PagePool(n_pages, ps, n_slots, self.max_pages)
        # KV-cache dtype: explicit override > the embed leaf's dtype >
        # cfg.dtype. A weight-only int8 tree (quant.quantize_tree) stores
        # the embed leaf as a {"q","s"} dict, which jnp.result_type used
        # to crash on — quantized trees fall back to the config dtype.
        if cache_dtype is not None:
            dtype = jnp.dtype(cache_dtype)
        elif quant.is_quantized(params["embed"]):
            dtype = jnp.dtype(cfg.dtype)
        else:
            dtype = jnp.result_type(params["embed"])
        self.cache_dtype = dtype
        self.cache = lm.init_paged_cache(cfg, n_slots, max_len,
                                         page_size=ps, n_pages=n_pages,
                                         dtype=dtype)
        if buckets is not None:
            if not supports_bucketing(cfg):
                raise ValueError(
                    f"{cfg.name} carries recurrent/MoE prefill state: "
                    "padded buckets are inexact, prompts must prefill at "
                    "exact lengths (omit `buckets`)")
            self.buckets: Optional[List[int]] = sorted(buckets)
            if self.buckets[-1] < max_len:
                raise ValueError(
                    f"largest bucket {self.buckets[-1]} must cover "
                    f"max_len={max_len} (every admissible prompt length)")
        elif supports_bucketing(cfg):
            self.buckets = default_buckets(max_len, paging.min_bucket)
        else:
            self.buckets = None      # exact-length prefill (recurrent/MoE)

        self.lengths = jnp.zeros((n_slots,), jnp.int32)
        self._host_len = np.zeros((n_slots,), np.int64)
        self._last = jnp.zeros((n_slots, 1), jnp.int32)
        self._temps = jnp.zeros((n_slots,), jnp.float32)
        self._tables_dev = jnp.asarray(self.pool.tables)
        self._tables_version = self.pool.version
        self.active: List[Optional[Request]] = [None] * n_slots
        self.out_tokens: List[List[int]] = [[] for _ in range(n_slots)]
        self.started = [0.0] * n_slots
        self.ttft = [0.0] * n_slots
        self.queue: deque = deque()  # (Request, submission wall time)
        self._prefill_lens: set = set()   # distinct padded lengths seen
        self._stepped = False
        self.completed: List[Completion] = []
        self.kv_trace: List[List[int]] = []   # per-step live slot lengths

        def step_fn(params, cache, tokens, lengths, tables, temps, active,
                    key):
            logits, cache = lm.decode_step(params, cache, tokens, lengths,
                                           cfg, pages=tables)
            nxt = sampling.sample(logits, key, temperature=temps)
            # idle slots stay parked at length 0 writing the trash page
            new_lengths = jnp.where(active, lengths + 1, 0)
            return nxt, new_lengths, cache

        def admit_fn(params, cache, lengths, last, tokens, slot, pages_row,
                     plen, temp, key):
            logits, states = lm.prefill_states(params, tokens, cfg,
                                               last_pos=plen[None])
            cache = lm.insert_prefill(cfg, cache, states, slot=slot,
                                      pages=pages_row, plen=plen,
                                      page_size=ps)
            first = sampling.sample(logits, key, temperature=temp[None])[0]
            lengths = lengths.at[slot].set(plen)
            last = last.at[slot, 0].set(first)
            return first, cache, lengths, last

        # donate the cache: the pool update aliases in place instead of
        # copying the whole (R, n_pages+1, ps, Hkv, hd) pools every step
        self._step = jax.jit(step_fn, donate_argnums=(1,))
        self._admit = jax.jit(admit_fn, donate_argnums=(1,))

    # ------------------------------------------------------------------

    def submit(self, req: Request):
        plen = int(req.prompt.shape[0])
        if not 0 < plen <= self.max_len:
            raise ValueError(f"prompt of length {plen} cannot decode "
                             f"within max_len={self.max_len}")
        if req.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {req.max_new} "
                             "(every request produces the prefill token)")
        if plen == self.max_len and req.max_new > 1:
            # prefill-only request: admission writes exactly max_len KV
            # rows and the prefill-sampled token retires it — there is
            # no in-bounds cache row left for a decode step to write
            req = dataclasses.replace(req, max_new=1)
        self.queue.append((req, time.perf_counter()))

    def compile_counts(self) -> dict:
        """Compiled-program counts of the two serving entry points —
        jax's jit cache size when available (ground truth), else the
        host-side proxy (distinct padded prefill lengths map 1:1 to
        compiled admit programs; one decode program once any step ran)."""
        def n(fn, fallback):
            return fn._cache_size() if hasattr(fn, "_cache_size") \
                else fallback
        return {"prefill": n(self._admit, len(self._prefill_lens)),
                "step": n(self._step, int(self._stepped))}

    def _req_temp(self, req: Request) -> float:
        return self.temperature if req.temperature is None else \
            req.temperature

    def _fill_slots(self) -> int:
        admitted = 0
        for slot in range(self.n_slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req, t0 = self.queue[0]   # t0: submission time (TTFT base)
            plen = int(req.prompt.shape[0])
            # KV rows ever written: the prompt plus one row per decode
            # step (the final sampled token is returned, never written)
            worst = min(self.max_len, plen + req.max_new - 1)
            if not self.pool.can_admit(worst):
                break                # FIFO: wait for pages, don't skip
            self.queue.popleft()
            admitted += 1
            self.pool.admit(slot, worst)
            self.pool.ensure(slot, plen)
            bl = bucket_for(plen, self.buckets) if self.buckets else plen
            self._prefill_lens.add(bl)
            padded = np.zeros((1, bl), np.int32)
            padded[0, :plen] = np.asarray(req.prompt)
            self.key, sk = jax.random.split(self.key)
            first, self.cache, self.lengths, self._last = self._admit(
                self.params, self.cache, self.lengths, self._last,
                jnp.asarray(padded), jnp.int32(slot),
                jnp.asarray(self.pool.tables[slot]), jnp.int32(plen),
                jnp.float32(self._req_temp(req)), sk)
            self._temps = self._temps.at[slot].set(self._req_temp(req))
            self.active[slot] = req
            self.out_tokens[slot] = [int(first)]
            self.started[slot] = t0
            self.ttft[slot] = time.perf_counter() - t0
            self._host_len[slot] = plen
            # the prefill-sampled token can already finish the request
            if self.out_tokens[slot][0] == self.eos_id or req.max_new <= 1:
                self._retire(slot)
        return admitted

    def _retire(self, slot):
        req = self.active[slot]
        self.completed.append(Completion(
            rid=req.rid, tokens=list(self.out_tokens[slot]),
            prompt_len=int(req.prompt.shape[0]),
            latency_s=time.perf_counter() - self.started[slot],
            ttft_s=self.ttft[slot]))
        self.pool.release(slot)
        self.active[slot] = None
        self.out_tokens[slot] = []
        self._host_len[slot] = 0

    def run(self, max_steps: int = 10_000) -> List[Completion]:
        """Continuous-batching loop until queue + slots drain."""
        steps = 0
        self.kv_trace = []           # fresh trace per run (bounded host mem)
        while any(a is not None for a in self.active) or self.queue:
            admitted = self._fill_slots()
            active = np.asarray([a is not None for a in self.active])
            if not active.any():
                if self.queue and not admitted:
                    raise RuntimeError(
                        "request needs more KV pages than the pool holds "
                        f"({self.pool.n_pages} x {self.page_size} tokens)")
                if self.queue:
                    continue         # everything admitted retired at once
                break
            for slot in np.flatnonzero(active):
                # cover the position this step writes (lazy tail alloc)
                self.pool.ensure(int(slot), int(self._host_len[slot]) + 1)
            if self.pool.version != self._tables_version:
                self._tables_dev = jnp.asarray(self.pool.tables)
                self._tables_version = self.pool.version
            self.key, sk = jax.random.split(self.key)
            nxt, self.lengths, self.cache = self._step(
                self.params, self.cache, self._last, self.lengths,
                self._tables_dev, self._temps, jnp.asarray(active), sk)
            self._last = nxt[:, None]
            self._stepped = True
            nxt_host = jax.device_get(nxt)  # the step's ONE device fetch
            self._host_len[active] += 1
            self._host_len[~active] = 0
            self.kv_trace.append(
                [int(self._host_len[s]) for s in np.flatnonzero(active)])
            for slot in np.flatnonzero(active):
                slot = int(slot)
                req = self.active[slot]
                tok = int(nxt_host[slot])
                self.out_tokens[slot].append(tok)
                done = (tok == self.eos_id
                        or len(self.out_tokens[slot]) >= req.max_new
                        or int(self._host_len[slot]) >= self.max_len - 1)
                if done:
                    self._retire(slot)
            steps += 1
            if steps >= max_steps:
                break
        return self.completed
