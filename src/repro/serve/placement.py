"""Device-placement policies for the serving engine.

The engine never talks to devices directly: a *placement* object owns
where parameters, page pools and the jitted entry points live, so the
same host loop serves one device or a tensor-parallel mesh (multi-host
later slots in as a third policy — ROADMAP).

``SingleDevice`` is the identity policy (exactly the pre-policy engine).

``TensorParallel`` is Megatron-style TP over a 1-D ``model`` mesh axis,
run inside ``compat.shard_map`` so the existing model code traces
unchanged against a *local* config (heads / d_ff divided by the shard
count):

  * fused wqkv / wgi panels (DESIGN.md §5) are column-sharded
    **segment-wise**: the stored columns are permuted into per-shard
    order ``[q_0|k_0|v_0 | q_1|k_1|v_1 | ...]`` first, so the plain
    contiguous split hands every shard a valid local fused panel and
    the in-kernel segment slicing (``proj_splits`` of the local cfg)
    still lands on projection boundaries. GQA grouping survives because
    q heads are stored grouped per kv head and the shard count divides
    ``n_kv_heads``;
  * attention ``wo`` and the MLP down projection are row-sharded along
    the contraction dim (contiguous head- / channel-major rows — no
    permutation needed); their matmuls yield K-partial sums finished by
    one ``psum`` per projection (``partitioning.tp_reduce``), with
    bias / residual applied strictly after;
  * per-layer page pools shard on the KV-head axis — each shard's
    decode gathers touch only its own heads' pages;
  * an untied ``lm_head`` vocab-shards (exact N-split) and the logits
    all-gather back; tied embeddings stay replicated;
  * block tables, lengths, temperatures, tokens and the ``PagePool``
    free list stay host-side / replicated — the host loop is oblivious;
  * the speculative *verify* entry point shards exactly like chunk
    prefill: a replicated ``(B, 1+k)`` token panel in, head-sharded
    paged writes, all-gathered panel logits out. No new placement code
    — ``Placement.jit`` sees one more (PARAMS, CACHE, REP...) program.

Weight-only int8 ``{"q", "s"}`` leaves shard with their weight: scales
are per-output-channel, so column-sharded panels permute / split the
scale row identically and row-sharded projections replicate it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import compat, partitioning, quant
from repro.core.types import GATED_ACTS, ModelConfig
from repro.models import attention, lm
from repro.serve.paging import supports_bucketing

# argument-kind sentinels for Placement.jit: how each operand is placed
PARAMS = "params"        # the prepared (sharded) parameter tree
CACHE = "cache"          # the prepared (sharded) paged cache tree
REP = "rep"              # replicated host value (tokens, tables, key...)


class SingleDevice:
    """Identity placement: everything on the default device."""

    n_shards = 1
    axis: Optional[str] = None

    def validate(self, cfg: ModelConfig) -> None:
        pass

    def compute_cfg(self, cfg: ModelConfig) -> ModelConfig:
        return cfg

    def prepare_params(self, params, cfg: ModelConfig):
        return params

    def prepare_cache(self, cache):
        return cache

    def put_rep(self, x):
        return x

    def jit(self, fn, *, kinds: Sequence[str], out_kinds: Sequence[str],
            donate: Sequence[int] = ()):
        return jax.jit(fn, donate_argnums=tuple(donate))

    def describe(self) -> str:
        return "single-device"


def shard_perm(widths: Sequence[int], t: int) -> np.ndarray:
    """Column permutation turning a fused multi-segment panel into
    per-shard order: segment s has ``widths[s]`` columns; shard i's
    slice of EVERY segment lands contiguously at block i, so a plain
    t-way split of the permuted axis yields valid local fused panels."""
    offs = np.concatenate([[0], np.cumsum(widths)])[:-1]
    idx = []
    for s in range(t):
        for o, w in zip(offs, widths):
            p = w // t
            idx.extend(range(o + s * p, o + (s + 1) * p))
    return np.asarray(idx, np.int64)


def _permute_cols(leaf, idx):
    if quant.is_quantized(leaf):
        return {"q": leaf["q"][..., idx], "s": leaf["s"][..., idx]}
    return leaf[..., idx]


def _col_spec(leaf, axis):
    """Shard the output (last) axis; int8 scales are per-output-channel
    and split with it."""
    if quant.is_quantized(leaf):
        return {"q": P(*([None] * (leaf["q"].ndim - 1)), axis),
                "s": P(*([None] * (leaf["s"].ndim - 1)), axis)}
    return P(*([None] * (leaf.ndim - 1)), axis)


def _row_spec(leaf, axis):
    """Shard the contraction (second-to-last) axis; int8 scales are
    per-output-channel => replicated."""
    if quant.is_quantized(leaf):
        return {"q": P(*([None] * (leaf["q"].ndim - 2)), axis, None),
                "s": P(*([None] * leaf["s"].ndim))}
    return P(*([None] * (leaf.ndim - 2)), axis, None)


def _rep_spec(leaf):
    if quant.is_quantized(leaf):
        return {"q": P(*([None] * leaf["q"].ndim)),
                "s": P(*([None] * leaf["s"].ndim))}
    return P(*([None] * leaf.ndim))


class TensorParallel:
    """Head-/segment-sharded tensor parallelism over a 1-D mesh axis."""

    def __init__(self, n_shards: int, *, axis: str = "model"):
        if n_shards < 1:
            raise ValueError(f"mesh axis size must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.axis = axis
        self._mesh: Optional[Mesh] = None
        self._pspec = None           # params spec tree (set by prepare)
        self._cspec = None           # cache spec tree

    # -- validation (engine construction time, never mid-step) ---------

    def validate(self, cfg: ModelConfig) -> None:
        t = self.n_shards
        if not supports_bucketing(cfg):
            raise ValueError(
                f"{cfg.name}: tensor-parallel serving supports pure "
                "causal attention+MLP stacks only (recurrent/MoE/cross-"
                "attention state has no head sharding)")
        bad = []
        if cfg.n_heads % t:
            bad.append(f"n_heads={cfg.n_heads}")
        if cfg.n_kv_heads % t:
            bad.append(f"n_kv_heads={cfg.n_kv_heads}")
        if cfg.d_ff % t:
            seg = ("each wgi gate/up segment" if cfg.act in GATED_ACTS
                   else "the wi panel")
            bad.append(f"d_ff={cfg.d_ff} ({seg})")
        if not cfg.tie_embeddings and lm.padded_vocab(cfg) % t:
            bad.append(f"padded vocab={lm.padded_vocab(cfg)}")
        if bad:
            raise ValueError(
                f"mesh axis '{self.axis}'={t} cannot shard {cfg.name}: "
                f"it must divide every fused-panel segment and head "
                f"count (DESIGN.md §5), but not: " + ", ".join(bad))

    def compute_cfg(self, cfg: ModelConfig) -> ModelConfig:
        """The per-shard config the model code traces against."""
        t = self.n_shards
        return dataclasses.replace(cfg, n_heads=cfg.n_heads // t,
                                   n_kv_heads=cfg.n_kv_heads // t,
                                   d_ff=cfg.d_ff // t)

    # -- mesh ----------------------------------------------------------

    def mesh(self) -> Mesh:
        if self._mesh is None:
            devs = jax.devices()
            if len(devs) < self.n_shards:
                raise ValueError(
                    f"mesh axis '{self.axis}'={self.n_shards} needs "
                    f"{self.n_shards} devices, found {len(devs)} (set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                    "to emulate)")
            self._mesh = Mesh(np.array(devs[:self.n_shards]), (self.axis,))
        return self._mesh

    # -- parameter / cache placement -----------------------------------

    def prepare_params(self, params, cfg: ModelConfig):
        """Permute fused panels into per-shard segment order, build the
        spec tree, and device_put with NamedShardings."""
        t, ax = self.n_shards, self.axis
        mesh = self.mesh()
        qkv_idx = shard_perm(attention.proj_splits(cfg), t)
        gated = cfg.act in GATED_ACTS
        gi_idx = (shard_perm((cfg.d_ff, cfg.d_ff), t) if gated else None)

        def permute_fn(blk, p):
            p = dict(p)
            if blk.mixer == "attn" and "attn" in p:
                a = dict(p["attn"])
                a["wqkv"] = _permute_cols(a["wqkv"], qkv_idx)
                p["attn"] = a
            if blk.ffn == "mlp" and "ffn" in p and gated:
                f = dict(p["ffn"])
                f["wgi"] = _permute_cols(f["wgi"], gi_idx)
                p["ffn"] = f
            return p

        def spec_fn(blk, p):
            p = dict(p)
            if blk.mixer == "attn" and "attn" in p:
                a = dict(p["attn"])
                a["wqkv"] = _col_spec(a["wqkv"], ax)
                a["wo"] = _row_spec(a["wo"], ax)
                p["attn"] = a
            if blk.ffn == "mlp" and "ffn" in p:
                f = dict(p["ffn"])
                key = "wgi" if gated else "wi"
                f[key] = _col_spec(f[key], ax)
                f["wo"] = _row_spec(f["wo"], ax)
                p["ffn"] = f
            return p

        permuted = lm._migrate_blocks(cfg, params, permute_fn)
        chimera = lm._migrate_blocks(cfg, permuted, spec_fn)
        isP = lambda x: isinstance(x, P)                   # noqa: E731
        specs = jax.tree.map(
            lambda leaf: leaf if isP(leaf) else _rep_spec(leaf),
            chimera, is_leaf=isP)
        if not cfg.tie_embeddings:
            specs["lm_head"] = _col_spec(params["lm_head"], ax)
        self._pspec = specs
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 specs, is_leaf=isP)
        return jax.device_put(permuted, shardings)

    def prepare_cache(self, cache):
        """Paged KV pools (R, n_pages + n_slots, ps, Hkv, hd) shard on
        the KV-head axis — each shard's page gathers stream only its own
        heads. Everything else in the tree is rejected by validate()."""
        ax = self.axis
        mesh = self.mesh()

        def spec(leaf):
            assert leaf.ndim == 5, (
                "TP cache holds paged attention pools only, got rank "
                f"{leaf.ndim}")
            return P(None, None, None, ax, None)

        self._cspec = jax.tree.map(spec, cache)
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 self._cspec, is_leaf=lambda x:
                                 isinstance(x, P))
        return jax.device_put(cache, shardings)

    def put_rep(self, x):
        """Commit a replicated engine-state array to the mesh. The jit
        signature includes operand shardings: recurring operands that
        start host-side but come back as shard_map outputs (lengths,
        last tokens) would otherwise retrace every entry point once and
        break the compile-count bound."""
        return jax.device_put(x, NamedSharding(self.mesh(), P()))

    # -- jit -----------------------------------------------------------

    def jit(self, fn, *, kinds: Sequence[str], out_kinds: Sequence[str],
            donate: Sequence[int] = ()):
        """Wrap an engine entry point in shard_map over the mesh. kinds
        name each positional arg's placement (PARAMS / CACHE / REP);
        PARAMS and CACHE expand to the spec trees recorded by prepare_*
        (prepare must run first). The traced body activates the TP shard
        context so the model's output projections psum."""
        mesh = self.mesh()
        assert self._pspec is not None and self._cspec is not None, \
            "prepare_params/prepare_cache must run before jit"

        def expand(kind):
            if kind == PARAMS:
                return self._pspec
            if kind == CACHE:
                return self._cspec
            return P()

        in_specs = tuple(expand(k) for k in kinds)
        out_specs = tuple(expand(k) for k in out_kinds)
        ax = self.axis

        def body(*args):
            with partitioning.tp_shard(ax):
                return fn(*args)

        mapped = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, check_vma=False)
        # pin output shardings to the exact NamedShardings put_rep /
        # prepare_* commit inputs to: shard_map alone emits equivalent
        # but unequal specs (P(None, None) vs P()), and a fed-back
        # output with a spec that hashes differently would specialize a
        # second executable per program — doubling the compile bound
        isP = lambda x: isinstance(x, P)                   # noqa: E731
        out_sh = tuple(jax.tree.map(
            lambda s: NamedSharding(mesh, s), expand(k), is_leaf=isP)
            for k in out_kinds)
        return jax.jit(mapped, donate_argnums=tuple(donate),
                       out_shardings=out_sh)

    def describe(self) -> str:
        return f"tensor-parallel {self.axis}={self.n_shards}"


def from_mesh_shape(spec: str):
    """Parse a ``--mesh-shape`` CLI value into a placement policy.
    Accepts '' / '1' (single device), 'N', or 'model=N'."""
    s = (spec or "").strip()
    if not s:
        return SingleDevice()
    axis = "model"
    if "=" in s:
        axis, _, s = s.partition("=")
        axis = axis.strip()
        if axis != "model":
            raise ValueError(
                f"unknown mesh axis '{axis}' in --mesh-shape (serving "
                "shards over the 'model' axis only, e.g. 'model=4')")
    try:
        n = int(s)
    except ValueError:
        raise ValueError(
            f"--mesh-shape '{spec}' is not 'N' or 'model=N'") from None
    if n < 1:
        raise ValueError(f"--mesh-shape size must be >= 1, got {n}")
    return SingleDevice() if n == 1 else TensorParallel(n, axis=axis)
