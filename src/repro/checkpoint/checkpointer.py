"""Sharded npz checkpointer: atomic, async, elastic.

Production requirements covered without external deps:

  * **Atomicity** — writes go to ``step_<N>.tmp/`` then ``os.rename`` to
    ``step_<N>/``; a crash mid-write never corrupts the latest good
    checkpoint. A ``latest`` marker file is updated last.
  * **Async** — ``save_async`` snapshots to host RAM (device_get) then
    writes on a background thread; the train loop keeps stepping.
  * **Sharded** — each host writes only the leaves (or leaf-shards) it
    owns; here (single host) the tree is chunked into multiple npz
    shards to mirror the layout.
  * **Elastic restore** — checkpoints store full (unsharded) arrays, so
    restore works under ANY mesh shape: the restored tree is re-placed
    with the target sharding via ``jax.device_put`` (reshard-on-load).
  * **Integrity** — a manifest json with per-shard checksums; restore
    verifies before use.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any,
         extra: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the final directory."""
    paths, leaves, _ = _flatten_with_paths(tree)
    host = [np.asarray(jax.device_get(leaf)) for leaf in leaves]
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    # chunk leaves into npz shards of bounded size
    shards, cur, cur_bytes = [], {}, 0
    for p, a in zip(paths, host):
        cur[p] = a
        cur_bytes += a.nbytes
        if cur_bytes >= _SHARD_BYTES:
            shards.append(cur)
            cur, cur_bytes = {}, 0
    if cur:
        shards.append(cur)

    manifest = {"step": step, "extra": extra or {}, "shards": []}
    for i, shard in enumerate(shards):
        fn = f"shard_{i:05d}.npz"
        fp = os.path.join(tmp, fn)
        np.savez(fp, **{k.replace("/", "|"): v for k, v in shard.items()})
        with open(fp, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["shards"].append({"file": fn, "keys": list(shard),
                                   "sha256": digest})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "latest.tmp"),
               os.path.join(ckpt_dir, "latest"))
    return final


class AsyncCheckpointer:
    """Snapshot-to-host then write on a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any,
                   extra: Optional[dict] = None):
        self.wait()
        # snapshot on the caller thread (device -> host), write async
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                tree)

        def work():
            save(self.ckpt_dir, step, snapshot, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(all_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    marker = os.path.join(ckpt_dir, "latest")
    if os.path.exists(marker):
        with open(marker) as f:
            s = int(f.read().strip())
        if os.path.isdir(os.path.join(ckpt_dir, f"step_{s:08d}")):
            return s
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Optional[Any] = None, verify: bool = True):
    """Restore into the structure of `like`; device_put with `shardings`
    (elastic: any target mesh works). Returns (tree, extra)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = {}
    for sh in manifest["shards"]:
        fp = os.path.join(d, sh["file"])
        if verify:
            with open(fp, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != sh["sha256"]:
                raise IOError(f"checksum mismatch in {fp}")
        with np.load(fp) as z:
            for k in z.files:
                arrays[k.replace("|", "/")] = z[k]

    paths, leaves, treedef = _flatten_with_paths(like)
    missing = [p for p in paths if p not in arrays]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    restored = []
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
    else:
        flat_sh = [None] * len(paths)
    for p, ref, sh in zip(paths, leaves, flat_sh):
        a = arrays[p].astype(ref.dtype) if hasattr(ref, "dtype") else arrays[p]
        restored.append(jax.device_put(a, sh) if sh is not None
                        else jax.numpy.asarray(a))
    return treedef.unflatten(restored), manifest["extra"]
