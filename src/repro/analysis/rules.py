"""AST rule pass: PagePool transaction discipline and decode-path
hygiene, as ruff-style diagnostics.

The pool invariants these rules prove (DESIGN.md §7/§8):

  RWA501  every ``pool.begin()`` reaches a ``commit()``/``rollback()``
          on **every normal exit path** (fall-through, return, break,
          continue, and each loop iteration must leave the transaction
          depth where it found it). ``raise`` paths are excused: the
          engine's recovery boundary drains open transactions
          (`while pool.in_transaction(): pool.rollback()`).
  RWA502  ``_make_room``/``reclaim`` (prefix-cache LRU eviction) must
          run strictly *before* ``begin``: a rollback restores
          refcounts but cannot resurrect a dropped radix-tree node, so
          an in-transaction eviction strands pages forever.
  RWA503  multi-page pool mutation (``admit``/``ensure``/``map_shared``
          /``cow``) only inside an open transaction — outside one, an
          ``AllocFault`` mid-sequence leaks a half-admission.
          (``release`` is exempt by design: it is a self-contained
          single-owner teardown the recovery path calls while *no*
          transaction can be live.)
  RWA504  no ``jnp.concatenate``/``stack`` in serving modules: a
          per-token weight-panel rebuild belongs in the fused param
          layout (DESIGN.md §5), and activation concats hide O(len)
          copies in the decode step.

The walker abstract-interprets each function over a *set* of possible
transaction depths (branches merge by union), which is exact for the
engine's shapes: straight-line begin/try/commit blocks with
early-continue and AllocFault rollbacks.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import List, Optional, Set

from repro.analysis.report import Diagnostic, PassResult

_MUTATORS = frozenset({"admit", "ensure", "map_shared", "cow"})
_EVICTORS = frozenset({"_make_room", "reclaim"})
_CONCATS = frozenset({"concatenate", "stack", "vstack", "hstack"})


def _call_attr(node: ast.AST) -> str:
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id
    return ""


def _on_pool(node: ast.Call) -> bool:
    """True for `<...>.pool.m(...)` or `pool.m(...)` receivers."""
    f = node.func
    if not isinstance(f, ast.Attribute):
        return False
    base = f.value
    if isinstance(base, ast.Attribute):
        return base.attr == "pool"
    if isinstance(base, ast.Name):
        return base.id == "pool"
    return False


@dataclasses.dataclass
class _TxWalker:
    path: str
    fname: str
    diags: List[Diagnostic] = dataclasses.field(default_factory=list)
    checked: int = 0

    def _diag(self, code: str, node: ast.AST, msg: str):
        self.diags.append(Diagnostic(
            code=code, message=f"{msg} (in {self.fname})",
            path=self.path, line=getattr(node, "lineno", 0)))

    # states: the set of possible open-transaction depths here
    def walk(self, body: List[ast.stmt],
             states: Set[int]) -> Optional[Set[int]]:
        """Returns the state set at fall-through, or None if every path
        exits (return/raise/break/continue)."""
        for stmt in body:
            states = self.stmt(stmt, states)
            if states is None:
                return None
        return states

    def _scan_calls(self, node: ast.AST, states: Set[int]):
        """Apply the eviction/mutation rules to every call under
        `node` — which must contain no nested *statements*, so the
        transaction state here is exact."""
        for call in [n for n in ast.walk(node)
                     if isinstance(n, ast.Call)]:
            attr = _call_attr(call)
            if attr in _EVICTORS and any(s > 0 for s in states):
                self.checked += 1
                self._diag("RWA502", call,
                           f"`{attr}` runs inside an open pool "
                           "transaction: rollback cannot resurrect an "
                           "evicted prefix-cache node")
            elif attr in _MUTATORS and _on_pool(call):
                self.checked += 1
                if 0 in states:
                    self._diag("RWA503", call,
                               f"pool.{attr}() outside a transaction: "
                               "an AllocFault here leaks a partial "
                               "admission")

    def stmt(self, stmt: ast.stmt,
             states: Set[int]) -> Optional[Set[int]]:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return states             # analysed as its own function
        # compound statements: rule-scan only their header expressions
        # here (their bodies recurse below, each at its own state)
        if isinstance(stmt, (ast.If, ast.While)):
            self._scan_calls(stmt.test, states)
        elif isinstance(stmt, ast.For):
            self._scan_calls(stmt.iter, states)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self._scan_calls(item.context_expr, states)
        elif not isinstance(stmt, ast.Try):
            self._scan_calls(stmt, states)

        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            attr = _call_attr(stmt.value)
            if _on_pool(stmt.value):
                if attr == "begin":
                    self.checked += 1
                    return {s + 1 for s in states}
                if attr in ("commit", "rollback"):
                    return {max(0, s - 1) for s in states}
            return states

        if isinstance(stmt, ast.If):
            a = self.walk(list(stmt.body), set(states))
            b = self.walk(list(stmt.orelse), set(states))
            if a is None and b is None:
                return None
            return (a or set()) | (b or set())

        if isinstance(stmt, (ast.For, ast.While)):
            end = self.walk(list(stmt.body), set(states))
            if end is not None and end != states:
                self._diag("RWA501", stmt,
                           "transaction depth changes across a loop "
                           f"iteration ({sorted(states)} -> "
                           f"{sorted(end)})")
            self.walk(list(stmt.orelse), set(states))
            return states

        if isinstance(stmt, ast.Try):
            body_end = self.walk(list(stmt.body), set(states))
            # a handler can enter at the state of ANY point in the body:
            # approximate with entry + fall-through states
            handler_entry = set(states) | (body_end or set())
            handler_ends: Set[int] = set()
            for h in stmt.handlers:
                he = self.walk(list(h.body), set(handler_entry))
                if he is not None:
                    handler_ends |= he
            else_end = self.walk(list(stmt.orelse),
                                 set(body_end if body_end is not None
                                     else states))
            out: Set[int] = set()
            if body_end is not None and not stmt.orelse:
                out |= body_end
            if else_end is not None:
                out |= else_end
            out |= handler_ends
            if stmt.finalbody:
                return self.walk(list(stmt.finalbody),
                                 out or set(states))
            return out if (out or handler_ends or body_end is not None
                           or else_end is not None) else None

        if isinstance(stmt, ast.With):
            return self.walk(list(stmt.body), states)

        if isinstance(stmt, (ast.Return, ast.Break, ast.Continue)):
            if any(s > 0 for s in states):
                kind = type(stmt).__name__.lower()
                self._diag("RWA501", stmt,
                           f"`{kind}` with an open pool transaction "
                           "(begin without commit/rollback on this "
                           "path)")
            return None

        if isinstance(stmt, ast.Raise):
            return None               # recovery boundary drains these

        return states


def audit_source(src: str, path: str = "<string>", *,
                 concat_rule: bool = True) -> PassResult:
    result = PassResult(name="rules")
    tree = ast.parse(src)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            w = _TxWalker(path=path, fname=node.name)
            end = w.walk(list(node.body), {0})
            if end is not None and any(s > 0 for s in end):
                w._diag("RWA501", node,
                        "function falls through with an open pool "
                        "transaction")
            result.diagnostics.extend(w.diags)
            result.checked += w.checked
    if concat_rule:
        for call in [n for n in ast.walk(tree) if isinstance(n, ast.Call)]:
            f = call.func
            if isinstance(f, ast.Attribute) and f.attr in _CONCATS and \
                    isinstance(f.value, ast.Name) and f.value.id == "jnp":
                result.checked += 1
                result.diagnostics.append(Diagnostic(
                    code="RWA504",
                    message=f"jnp.{f.attr} in a serving module: decode "
                            "must stream pre-fused panels, not rebuild "
                            "them per token",
                    path=path, line=call.lineno))
    return result


def audit_file(path: str, *, concat_rule: bool = True) -> PassResult:
    with open(path) as f:
        return audit_source(f.read(), path=path, concat_rule=concat_rule)
