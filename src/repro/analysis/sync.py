"""Sync-point pass: find hidden host<->device synchronisation in the
serving hot path.

The engine's latency contract (DESIGN.md §4/§7) is *one* device fetch
per step-loop iteration: the sampled tokens and their finite-ness flags
travel in a single ``jax.device_get``. Anything else that forces a
transfer — ``.item()``, ``int()/float()/bool()`` on a device array,
``np.asarray`` on a device value, a stray ``block_until_ready`` — adds
a blocking round-trip per call site and silently serialises the loop.

This pass runs an intra-procedural taint analysis over each module's
AST. Device-ness propagates forward from *producers*:

  * calls into ``jnp.* / jax.numpy.* / jax.random.* / jax.lax.* /
    jax.nn.*`` and ``jax.device_put``;
  * calls of configured device-returning methods (the engine's jitted
    ``self._step/_admit/_chunk`` entry points, ``placement.put_rep``);
  * ``self.X`` attribute loads where ``X`` was ever assigned a tainted
    value in the class (collected to a fixpoint across methods);
  * attribute loads whose name matches a dataclass field annotated
    ``jnp.ndarray`` anywhere in the module — a user-supplied device
    array travels under that name whatever object carries it.

Taint dies where host-ness is guaranteed: ``jax.device_get(...)``
results, and ``.shape/.dtype/.ndim/.size`` metadata reads (those are
tracer-safe). Unknown calls conservatively forward the taint of their
arguments. Sinks raise diagnostics (RWA101/102/103/105); the count of
``jax.device_get`` call sites per function is matched against an
explicit allowlist (RWA104) so a refactor that adds "just one more
fetch" fails the audit instead of doubling step latency.

Purely syntactic and flow-approximate by design (branches merge by
union), so it can run on every commit without tracing anything.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.report import Diagnostic, PassResult

# attribute reads that return host metadata, never device bytes
_META_ATTRS = frozenset({"shape", "dtype", "ndim", "size", "itemsize",
                         "weak_type", "sharding"})
# dotted-name prefixes whose calls produce device values
_PRODUCER_PREFIXES = ("jnp.", "jax.numpy.", "jax.random.", "jax.lax.",
                      "jax.nn.", "jax.device_put", "jax.jit")
# numpy constructors that materialise their argument on the host
_NP_SINKS = frozenset({"asarray", "array", "concatenate", "stack",
                       "ascontiguousarray", "copy"})


@dataclasses.dataclass
class SyncPolicy:
    """What the audited module is allowed to do.

    ``device_get_allow`` maps function name -> sanctioned number of
    ``jax.device_get`` call sites (unlisted functions get 0). The
    engine profile sanctions exactly one per step-loop phase:
    ``run`` / ``_fill_slots`` / ``_advance_chunks``.
    """
    device_get_allow: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    device_methods: Tuple[str, ...] = ("_step", "_admit", "_chunk",
                                       "_spec", "put_rep")
    # names bound to device-returning callables (`put = placement.put_rep`)
    device_aliases: Tuple[str, ...] = ("put",)


def _dotted(node: ast.AST) -> str:
    """'jax.random.split' for the callee of jax.random.split(...)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _device_dataclass_fields(tree: ast.Module) -> Set[str]:
    """Field names annotated `jnp.ndarray` in any class of the module:
    values travelling under these names are device arrays by contract,
    so reading one and materialising it on the host is a sync."""
    fields: Set[str] = set()
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) and \
                    isinstance(stmt.target, ast.Name):
                ann = stmt.annotation
                name = _dotted(ann) if isinstance(
                    ann, (ast.Attribute, ast.Name)) else ""
                if name in ("jnp.ndarray", "jax.Array", "jnp.array"):
                    fields.add(stmt.target.id)
    return fields


class _FunctionTaint(ast.NodeVisitor):
    """One function body: forward taint, record diagnostics."""

    def __init__(self, path: str, fname: str, policy: SyncPolicy,
                 device_attrs: Set[str], device_fields: Set[str]):
        self.path, self.fname, self.policy = path, fname, policy
        self.device_attrs = device_attrs      # self.X names (mutated!)
        self.device_fields = device_fields
        self.tainted: Set[str] = set()
        self.diags: List[Diagnostic] = []
        # distinct call *sites* (loop bodies walk twice for the taint
        # fixpoint — a site must not count per walk)
        self.device_get_sites: Set[int] = set()
        self.checked = 0

    @property
    def device_gets(self) -> int:
        return len(self.device_get_sites)

    # -- expression taint ------------------------------------------------

    def taint_of(self, node: Optional[ast.AST]) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _META_ATTRS:
                return False          # metadata read kills the taint
            if isinstance(node.value, ast.Name) and \
                    node.value.id == "self":
                return node.attr in self.device_attrs
            if node.attr in self.device_fields:
                return True
            return self.taint_of(node.value)
        if isinstance(node, ast.Call):
            return self.call_taint(node)
        if isinstance(node, ast.Subscript):
            return self.taint_of(node.value)
        if isinstance(node, ast.BinOp):
            return self.taint_of(node.left) or self.taint_of(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.taint_of(node.operand)
        if isinstance(node, ast.Compare):
            return self.taint_of(node.left) or \
                any(self.taint_of(c) for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return any(self.taint_of(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return self.taint_of(node.body) or self.taint_of(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.taint_of(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.taint_of(node.value)
        return False

    def call_taint(self, node: ast.Call) -> bool:
        callee = _dotted(node.func)
        args_tainted = any(self.taint_of(a) for a in node.args) or \
            any(self.taint_of(kw.value) for kw in node.keywords)
        if callee == "jax.device_get":
            self.device_get_sites.add(id(node))
            return False              # the sanctioned fetch: host after
        if callee.startswith(_PRODUCER_PREFIXES):
            return True
        if callee.split(".")[0] in self.policy.device_aliases:
            return True
        # sinks ---------------------------------------------------------
        if isinstance(node.func, ast.Attribute):
            attr = node.func.attr
            base_tainted = self.taint_of(node.func.value)
            if attr == "item" and base_tainted:
                self._diag("RWA101", node,
                           "`.item()` on a device value blocks on a "
                           "device->host transfer")
                return False
            if attr == "block_until_ready" and base_tainted:
                self._diag("RWA105", node,
                           "block_until_ready() serialises the step "
                           "loop outside a sanctioned fetch")
                return base_tainted
            if attr in _NP_SINKS and callee.startswith("np.") and \
                    args_tainted:
                self._diag("RWA103", node,
                           f"np.{attr}() on a device value is a hidden "
                           "device->host sync")
                return False          # result is host-resident
            if attr in self.policy.device_methods:
                return True
        if isinstance(node.func, ast.Name):
            if node.func.id in ("int", "float", "bool") and args_tainted:
                self._diag("RWA102", node,
                           f"{node.func.id}() on a device value is a "
                           "hidden blocking sync")
                return False
            if node.func.id in self.policy.device_aliases:
                return True
        # unknown callable: forward the arguments' taint
        return args_tainted

    def _diag(self, code: str, node: ast.AST, msg: str):
        self.diags.append(Diagnostic(
            code=code, message=f"{msg} (in {self.fname})",
            path=self.path, line=getattr(node, "lineno", 0)))

    # -- statement walk --------------------------------------------------

    def run(self, fn: ast.FunctionDef):
        self.exec_body(fn.body)
        allowed = self.policy.device_get_allow.get(self.fname, 0)
        self.checked += 1             # the per-function fetch contract
        if self.device_gets != allowed and (self.device_gets or allowed):
            self.diags.append(Diagnostic(
                code="RWA104",
                message=(f"{self.fname} has {self.device_gets} "
                         f"jax.device_get site(s), contract allows "
                         f"{allowed}"),
                path=self.path, line=fn.lineno))

    def exec_body(self, body: List[ast.stmt]):
        for stmt in body:
            self.exec_stmt(stmt)

    def exec_stmt(self, stmt: ast.stmt):
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            tainted = self.taint_of(value)
            self.checked += 1
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            for tgt in targets:
                self.assign(tgt, value, tainted)
        elif isinstance(stmt, ast.Expr):
            self.taint_of(stmt.value)
            self.checked += 1
        elif isinstance(stmt, (ast.If,)):
            self.taint_of(stmt.test)
            self.exec_body(stmt.body)
            self.exec_body(stmt.orelse)
        elif isinstance(stmt, (ast.For, ast.While)):
            if isinstance(stmt, ast.For):
                self.assign(stmt.target, None,
                            self.taint_of(stmt.iter))
            else:
                self.taint_of(stmt.test)
            # two passes approximate the loop fixpoint (taint introduced
            # late in the body reaches uses at the top)
            self.exec_body(stmt.body)
            self.exec_body(stmt.body)
            self.exec_body(stmt.orelse)
        elif isinstance(stmt, ast.Try):
            self.exec_body(stmt.body)
            for h in stmt.handlers:
                self.exec_body(h.body)
            self.exec_body(stmt.orelse)
            self.exec_body(stmt.finalbody)
        elif isinstance(stmt, ast.With):
            self.exec_body(stmt.body)
        elif isinstance(stmt, (ast.Return, ast.Raise, ast.Assert)):
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self.taint_of(child)
        # nested defs/classes are analysed as their own functions

    def assign(self, tgt: ast.AST, value: Optional[ast.AST],
               tainted: bool):
        if isinstance(tgt, (ast.Tuple, ast.List)):
            # elementwise only when the value side unpacks one-to-one
            if isinstance(value, (ast.Tuple, ast.List)) and \
                    len(value.elts) == len(tgt.elts):
                for t, v in zip(tgt.elts, value.elts):
                    self.assign(t, v, self.taint_of(v))
            else:
                for t in tgt.elts:
                    self.assign(t, None, tainted)
            return
        if isinstance(tgt, ast.Name):
            if tainted:
                self.tainted.add(tgt.id)
            else:
                self.tainted.discard(tgt.id)
        elif tainted and isinstance(tgt, ast.Attribute) and \
                isinstance(tgt.value, ast.Name) and tgt.value.id == "self":
            # never un-taint a self attribute: another method may still
            # hold a device value under the same name
            self.device_attrs.add(tgt.attr)


def audit_source(src: str, path: str = "<string>", *,
                 policy: Optional[SyncPolicy] = None) -> PassResult:
    """Run the sync-point pass over one module's source text."""
    policy = policy or SyncPolicy()
    tree = ast.parse(src)
    device_fields = _device_dataclass_fields(tree)
    result = PassResult(name="sync")

    funcs: List[Tuple[str, ast.FunctionDef]] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            funcs.append((node.name, node))

    # fixpoint over self.X device attributes: a method assigning
    # `self.cache = self._step(...)` taints `self.cache` for every
    # other method; two rounds converge for assignment chains one deep
    # (all this codebase has), a third is cheap insurance
    device_attrs: Set[str] = set()
    for _ in range(3):
        before = set(device_attrs)
        for fname, fn in funcs:
            probe = _FunctionTaint(path, fname, policy, device_attrs,
                                   device_fields)
            probe.exec_body(fn.body)
        if device_attrs == before:
            break

    for fname, fn in funcs:
        ft = _FunctionTaint(path, fname, policy, set(device_attrs),
                            device_fields)
        # keep attr discoveries local to the reporting run
        ft.device_attrs = set(device_attrs)
        ft.run(fn)
        result.diagnostics.extend(ft.diags)
        result.checked += ft.checked
    return result


def audit_file(path: str, *, policy: Optional[SyncPolicy] = None) \
        -> PassResult:
    with open(path) as f:
        return audit_source(f.read(), path=path, policy=policy)


def audit_entry_jaxprs(entries, *, allow_callbacks: int = 0) -> PassResult:
    """Jaxpr side of the pass: the traced entry points themselves must
    not smuggle host round-trips in as callback primitives."""
    from repro.analysis import jaxprs as jxp
    result = PassResult(name="sync")
    for name, jaxpr in entries:
        cbs = jxp.callback_eqns(jaxpr)
        result.checked += 1
        if len(cbs) > allow_callbacks:
            result.diagnostics.append(Diagnostic(
                code="RWA106",
                message=(f"{len(cbs)} host-callback eqn(s) in traced "
                         f"entry point ({cbs[0].primitive.name})"),
                path=name))
    return result
