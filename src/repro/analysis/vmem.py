"""Pallas VMEM/scratch budget pass.

The row-wise kernels are planned against a per-core VMEM budget
(``plan_matmul``: ``geom.vmem_bytes`` minus 2 MB of headroom for
semaphores and runtime state — the paper's 149 KB-SRAM discipline at
TPU scale). The plan, however, is only a *model*: nothing stops a
kernel author from passing ``pallas_call`` block shapes the plan never
priced. This pass closes that gap by recomputing each traced kernel's
actual VMEM residency from the equation itself:

    2 x (input block bytes)       double-buffered HBM->VMEM pipeline
    + 1 x (output block bytes)    revisited across the K-innermost grid
    + 1 x (VMEM scratch bytes)    accumulators live across K steps

and failing any kernel above the modeled budget (RWA401), or above its
own plan's accounting when one is supplied (RWA402 — the model
undercounts, so the utilisation/ratio numbers built on it lie).

Works on any jaxpr: on CPU dev boxes, trace under
``runtime.use_impl('interpret')`` so the pallas lowering (and its
``grid_mapping``) appears in the graph.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.analysis.jaxprs import iter_eqns
from repro.analysis.report import Diagnostic, PassResult
from repro.core.rowwise import V5E

PLAN_HEADROOM = 2 * 1024 * 1024      # mirrors plan_matmul's budget


def _block_bytes(block_shape, dtype) -> int:
    size = 1
    for d in block_shape:
        # pallas marks grid-mapped (squeezed) dims with a non-int
        # sentinel; they occupy one element of that axis per step
        size *= int(d) if isinstance(d, int) else 1
    return size * dtype.itemsize


@dataclasses.dataclass(frozen=True)
class KernelFootprint:
    """Static VMEM residency of one ``pallas_call`` equation."""
    name: str
    grid: Tuple[int, ...]
    in_bytes: int                    # sum of input block bytes (single)
    out_bytes: int                   # sum of output block bytes
    scratch_bytes: int               # VMEM scratch (SMEM excluded)

    @property
    def resident_bytes(self) -> int:
        return 2 * self.in_bytes + self.out_bytes + self.scratch_bytes


def kernel_footprints(jaxpr_like) -> List[KernelFootprint]:
    out = []
    for eqn in iter_eqns(jaxpr_like):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params["grid_mapping"]
        blocks = list(gm.block_mappings)
        n_in = gm.num_inputs
        in_b = sum(_block_bytes(bm.block_shape,
                                bm.array_shape_dtype.dtype)
                   for bm in blocks[:n_in])
        out_b = sum(_block_bytes(bm.block_shape,
                                 bm.array_shape_dtype.dtype)
                    for bm in blocks[n_in:])
        scratch = 0
        n_scratch = gm.num_scratch_operands
        if n_scratch:
            inner = eqn.params["jaxpr"]
            for v in inner.invars[-n_scratch:]:
                aval = v.aval
                if str(getattr(aval, "memory_space", "vmem")) != "vmem":
                    continue         # SMEM scalars don't charge VMEM
                scratch += _block_bytes(aval.shape, aval.dtype)
        out.append(KernelFootprint(
            name=str(eqn.params.get("name", "pallas_call")),
            grid=tuple(int(g) for g in gm.grid),
            in_bytes=in_b, out_bytes=out_b, scratch_bytes=scratch))
    return out


def audit_vmem(jaxpr_like, name: str = "graph", *,
               budget: Optional[int] = None) -> PassResult:
    """RWA401 for every traced kernel whose residency exceeds the
    modeled budget (default: ``V5E.vmem_bytes`` minus the planner's
    2 MB headroom)."""
    budget = budget if budget is not None \
        else V5E.vmem_bytes - PLAN_HEADROOM
    result = PassResult(name="vmem")
    for fp in kernel_footprints(jaxpr_like):
        result.checked += 1
        if fp.resident_bytes > budget:
            result.diagnostics.append(Diagnostic(
                code="RWA401", path=name,
                message=f"kernel `{fp.name}` grid={fp.grid} resident "
                        f"{fp.resident_bytes:,} B (2x{fp.in_bytes:,} in "
                        f"+ {fp.out_bytes:,} out + {fp.scratch_bytes:,} "
                        f"scratch) > budget {budget:,} B"))
    return result


def crosscheck_plan(jaxpr_like, plan, name: str = "matmul", *,
                    budget: Optional[int] = None) -> PassResult:
    """RWA402 when a traced kernel's actual residency exceeds what its
    ``TilePlan`` charged: the planner's utilisation and traffic numbers
    are built on ``plan.vmem_bytes``, so an undercount there corrupts
    every downstream roofline figure. Also applies the RWA401 budget."""
    result = audit_vmem(jaxpr_like, name, budget=budget)
    for fp in kernel_footprints(jaxpr_like):
        result.checked += 1
        if fp.resident_bytes > plan.vmem_bytes:
            result.diagnostics.append(Diagnostic(
                code="RWA402", path=name,
                message=f"kernel `{fp.name}` resident "
                        f"{fp.resident_bytes:,} B exceeds its plan's "
                        f"accounting ({plan.vmem_bytes:,} B): "
                        "plan_matmul undercounts this launch"))
    return result
