"""Compile-bound enumeration pass: a closed-form proof of the engine's
jit-cache bound, replacing trust in runtime counters.

The serving contract (DESIGN.md §4/§7) bounds the compiled programs per
placement at::

    n_buckets  +  n_chunk_shapes  +  n_step_widths
    (prefill)     (chunked prefill)  (decode; 1, or the pow2 ladder
                                      under table-width bucketing)

This module *enumerates* the reachable shape-signature sets from a
:class:`~repro.core.types.PagingConfig` alone — no tracing, no engine —
by replaying the same host-side decisions the engine makes
(``bucket_for``, ``chunk_schedule``, ``_table_width``). Because both
sides derive from ``serve.paging``, the enumeration and the runtime can
only disagree if someone adds a new shape source to the engine — which
is exactly the event the audit exists to catch.

Two consumers:

  * :func:`enumerate_programs` + :func:`audit_bound` — static: assert
    the enumerated set equals the documented bound, per placement.
  * :func:`predict_compile_counts` + :func:`check_engine_counts` —
    workload-level: given concrete prompt lengths, predict the *exact*
    per-entry-point program counts a fault-free run compiles, and match
    them against ``Engine.compile_counts()`` (jit-cache ground truth).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence, Tuple

from repro.analysis.report import Diagnostic, PassResult
from repro.serve.paging import (bucket_for, chunk_schedule,
                                default_buckets, spec_ladder)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _width_for(hi: int, max_pages: int) -> int:
    """Mirror of ``Engine._table_width`` for ``hi`` live pages."""
    width = 1 if hi <= 1 else 1 << (hi - 1).bit_length()
    return min(width, max_pages)


@dataclasses.dataclass(frozen=True)
class ProgramInventory:
    """The reachable shape-signature set of one engine configuration.
    One compiled program per element, per placement."""
    prefill_lens: Tuple[int, ...]    # padded one-shot prefill lengths
    chunk_shapes: Tuple[int, ...]    # chunk panel widths
    step_widths: Tuple[int, ...]     # decode block-table widths
    spec_shapes: Tuple[int, ...] = ()  # verify panel widths (1 + k-ladder)

    @property
    def bound(self) -> int:
        return (len(self.prefill_lens) + len(self.chunk_shapes)
                + len(self.step_widths) + len(self.spec_shapes))


def enumerate_programs(*, max_len: int, page_size: int,
                       prefill_chunk: int = 0, min_bucket: int = 16,
                       buckets: Optional[Sequence[int]] = None,
                       table_width_bucketing: bool = False,
                       speculate_k: int = 0,
                       bucketing: bool = True) -> ProgramInventory:
    """Statically enumerate every shape signature the engine can hand
    its jitted entry points. ``bucketing=False`` models the
    recurrent/MoE exact-length prefill archs, whose prefill set is the
    (unbounded) set of submitted lengths — represented as empty here;
    only the decode side stays provable for them. ``speculate_k``
    enumerates the verify panel widths ``1 + paging.spec_ladder(k)``
    (speculation requires a bucketing-capable arch and full-width
    tables, so the set never multiplies against the width ladder)."""
    if bucketing:
        ladder = tuple(sorted(buckets)) if buckets is not None \
            else tuple(default_buckets(max_len, min_bucket))
    else:
        ladder = ()
    chunks = tuple(b for b in ladder if prefill_chunk
                   and b <= prefill_chunk)
    max_pages = _ceil_div(max_len, page_size)
    if table_width_bucketing:
        widths = tuple(sorted({_width_for(hi, max_pages)
                               for hi in range(max_pages + 1)}))
    else:
        widths = (max_pages,)
    specs = tuple(1 + w for w in spec_ladder(speculate_k)) \
        if bucketing else ()
    return ProgramInventory(prefill_lens=ladder, chunk_shapes=chunks,
                            step_widths=widths, spec_shapes=specs)


def audit_bound(inv: ProgramInventory, *, n_buckets: int,
                n_chunk_shapes: int, max_pages: int,
                table_width_bucketing: bool = False,
                n_spec_shapes: int = 0,
                name: str = "engine") -> PassResult:
    """Check the enumeration against the documented closed form:
    ``n_buckets + n_chunk_shapes + 1 + n_spec_shapes`` programs, the +1
    decode program growing to the ``log2(max_pages)+1``-entry pow2
    width ladder under table-width bucketing, and ``n_spec_shapes``
    being the documented verify k-ladder length (DESIGN.md §7/§10)."""
    result = PassResult(name="compile-bound")
    result.checked = 4
    if len(inv.spec_shapes) != n_spec_shapes:
        result.diagnostics.append(Diagnostic(
            code="RWA301", path=name,
            message=f"{len(inv.spec_shapes)} reachable verify panel "
                    f"shapes, documented k-ladder length is "
                    f"{n_spec_shapes}"))
    if len(inv.prefill_lens) != n_buckets:
        result.diagnostics.append(Diagnostic(
            code="RWA301", path=name,
            message=f"{len(inv.prefill_lens)} reachable prefill shapes, "
                    f"documented bound is n_buckets={n_buckets}"))
    if len(inv.chunk_shapes) != n_chunk_shapes:
        result.diagnostics.append(Diagnostic(
            code="RWA301", path=name,
            message=f"{len(inv.chunk_shapes)} reachable chunk shapes, "
                    f"documented bound is {n_chunk_shapes}"))
    if table_width_bucketing:
        # ladder entries: widths 1, 2, 4, ..., capped at max_pages —
        # at most log2(max_pages) + 2 and at least 2 for max_pages > 1
        cap = (max_pages - 1).bit_length() + 2 if max_pages > 1 else 1
        ok = 1 <= len(inv.step_widths) <= cap and \
            inv.step_widths[-1] == max_pages
        if not ok:
            result.diagnostics.append(Diagnostic(
                code="RWA301", path=name,
                message=f"step-width ladder {inv.step_widths} escapes "
                        f"the log2(max_pages)+1 bound (max_pages="
                        f"{max_pages})"))
    elif inv.step_widths != (max_pages,):
        result.diagnostics.append(Diagnostic(
            code="RWA301", path=name,
            message=f"decode widths {inv.step_widths}: exactly one "
                    "program (full table width) is documented"))
    return result


def predict_compile_counts(prompt_lens: Iterable[int], *, max_len: int,
                           prefill_chunk: int = 0,
                           min_bucket: int = 16,
                           buckets: Optional[Sequence[int]] = None,
                           bucketing: bool = True,
                           decode_steps: bool = True) -> Dict[str, int]:
    """Exact per-entry-point program counts a fault-free, prefix-cache-
    free run over ``prompt_lens`` compiles: each prompt either pads to
    its bucket (one-shot prefill) or splits into ``chunk_schedule``
    panels; decode compiles one program when any decode step runs."""
    ladder = (sorted(buckets) if buckets is not None
              else default_buckets(max_len, min_bucket)) if bucketing \
        else None
    prefill, chunks = set(), set()
    for plen in prompt_lens:
        if prefill_chunk and plen > prefill_chunk:
            for _, _, shape in chunk_schedule(plen, prefill_chunk,
                                              ladder):
                chunks.add(shape)
        elif ladder is not None:
            prefill.add(bucket_for(plen, ladder))
        else:
            prefill.add(plen)
    return {"prefill": len(prefill), "chunk": len(chunks),
            "step": 1 if decode_steps else 0}


def check_engine_counts(engine, expected: Dict[str, int],
                        name: str = "engine") -> PassResult:
    """Match ``Engine.compile_counts()`` (jit-cache ground truth) and
    the host-side proxies against a static prediction. Any drift means
    a shape source the enumeration does not model — the exact failure
    mode that silently multiplies compile time."""
    result = PassResult(name="compile-bound")
    actual = engine.compile_counts()
    proxies = {"prefill": len(engine._prefill_lens),
               "chunk": len(engine._chunk_shapes),
               "step": len(engine._step_widths),
               "spec": len(getattr(engine, "_spec_shapes", ()))}
    kinds = ("prefill", "chunk", "step")
    # the verify entry point is audited only when the prediction models
    # it (speculation off => both sides hold it at zero anyway)
    if "spec" in expected and "spec" in actual:
        kinds += ("spec",)
    for kind in kinds:
        result.checked += 1
        if actual[kind] != expected[kind]:
            result.diagnostics.append(Diagnostic(
                code="RWA303", path=name,
                message=f"{kind}: jit cache compiled {actual[kind]} "
                        f"program(s), static enumeration predicts "
                        f"{expected[kind]}"))
        if proxies[kind] != actual[kind]:
            result.diagnostics.append(Diagnostic(
                code="RWA303", path=name,
                message=f"{kind}: host proxy saw {proxies[kind]} "
                        f"shape(s) but the jit cache holds "
                        f"{actual[kind]} — a hidden operand is "
                        "fragmenting the cache"))
    return result


def weak_type_audit(entries) -> PassResult:
    """Flag weak_type invars on traced entry points: a Python-scalar
    operand compiles one program now and a second the moment a
    strongly-typed value of the same shape arrives (RWA302)."""
    from repro.analysis import jaxprs as jxp
    result = PassResult(name="compile-bound")
    for name, jaxpr in entries:
        result.checked += 1
        weak = jxp.weak_type_invars(jaxpr)
        if weak:
            result.diagnostics.append(Diagnostic(
                code="RWA302", path=name,
                message=f"{len(weak)} weak_type invar(s) "
                        f"(e.g. {weak[0].aval}): pass jnp.int32/"
                        "jnp.float32-typed operands"))
    return result
