"""Static invariant auditor CLI.

    PYTHONPATH=src python -m repro.analysis.audit [--arch deepseek-7b]
        [--mesh-shape 4] [--json out.json] [--passes sync,donation,...]

Runs all five passes (DESIGN.md §9) over the shipped serving entry
points of a reduced engine and exits non-zero on any error diagnostic,
so CI can gate merges on it:

  sync            AST taint over src/repro/serve/*.py + callback scan
                  of the traced entry points (one device fetch per
                  step-loop phase, nothing hidden)
  donation        every donated cache aliases an output in the lowered
                  MLIR of step/prefill/chunk
  compile-bound   static shape-signature enumeration == the documented
                  bound, for the plain and table-width-bucketed
                  configs; no weak_type operands in the entry points
  vmem            every pallas_call in the interpret-traced entry
                  points (and a large-K stress shape) fits the modeled
                  VMEM budget and its plan's accounting
  rules           PagePool transaction discipline + decode-path concat
                  rule over serve/engine.py / serve/paging.py

``--mesh-shape 4`` audits the TensorParallel placement; the CLI forces
the emulated device count into XLA_FLAGS *before* importing jax, so it
works on a single-CPU box (mirroring benchmarks/tp_bench.py).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.analysis.audit")
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--mesh-shape", type=int, default=1,
                    help="TensorParallel shard count (1 = single "
                         "device); emulated CPU devices are forced "
                         "before jax imports")
    ap.add_argument("--json", default="",
                    help="write per-pass results to this path")
    ap.add_argument("--passes", default="",
                    help="comma-separated subset (default: all five)")
    ap.add_argument("--explain", default="",
                    help="print the catalogue entry for a code and exit")
    return ap.parse_args(argv)


# sanctioned jax.device_get sites per engine function: THE serving
# latency contract. run(): the one decode fetch; _fill_slots(): the
# one-shot prefill's first token; _advance_chunks(): the final chunk's
# token (intermediate chunks stay async). Everything else in
# src/repro/serve is allowed zero.
ENGINE_SYNC_ALLOW = {"run": 1, "_fill_slots": 1, "_advance_chunks": 1}

SERVE_DIR_MODULES = ("engine.py", "paging.py", "sampling.py",
                     "placement.py", "prefix_cache.py", "faults.py",
                     "spec.py")
RULE_MODULES = ("engine.py", "paging.py", "prefix_cache.py")


def build_engine(arch: str, mesh: int):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.core.types import PagingConfig
    from repro.models import lm
    from repro.serve import placement as placement_mod
    from repro.serve.engine import Engine

    cfg = get_reduced(arch)
    placement = placement_mod.from_mesh_shape(
        str(mesh) if mesh > 1 else "")
    params, _ = lm.init_lm(jax.random.PRNGKey(0), cfg,
                           dtype=jnp.float32)
    return Engine(params, cfg, n_slots=2, max_len=64, eos_id=-1,
                  paging=PagingConfig(page_size=16, prefill_chunk=16,
                                      speculate_k=2),
                  placement=placement), cfg


def run_passes(arch: str, mesh: int, which=None):
    """Run the selected passes; returns a list of PassResult."""
    from repro.analysis import (compile_bound, donation, rules, sync,
                                vmem)
    from repro.core import runtime

    which = which or {"sync", "donation", "compile-bound", "vmem",
                      "rules"}
    serve_dir = os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "serve")
    results = []
    eng = cfg = None
    traced = []
    if which & {"sync", "donation", "compile-bound", "vmem"}:
        eng, cfg = build_engine(arch, mesh)
        # trace each entry point ONCE, under the interpret impl, and
        # share the jaxprs across passes: jitted functions cache their
        # trace by aval signature, so whichever impl traces first is
        # what every later make_jaxpr sees — and only the interpret
        # trace carries the pallas lowering the vmem pass reads
        import jax
        from repro.core import runtime
        with runtime.use_impl("interpret"):
            traced = [(n, jax.make_jaxpr(fn)(*args))
                      for n, fn, args, _ in eng.audit_entry_points()]

    if "sync" in which:
        t0 = time.perf_counter()
        res = sync.PassResult(name="sync")
        for mod in SERVE_DIR_MODULES:
            policy = sync.SyncPolicy(
                device_get_allow=ENGINE_SYNC_ALLOW
                if mod == "engine.py" else {})
            r = sync.audit_file(os.path.join(serve_dir, mod),
                                policy=policy)
            res.diagnostics += r.diagnostics
            res.checked += r.checked
        r = sync.audit_entry_jaxprs(traced)
        res.diagnostics += r.diagnostics
        res.checked += r.checked
        res.wall_s = time.perf_counter() - t0
        results.append(res)

    if "donation" in which:
        t0 = time.perf_counter()
        res = donation.PassResult(name="donation")
        for name, fn, args, donate in eng.audit_entry_points():
            r = donation.audit_donation(fn, args, donate, name=name)
            res.diagnostics += r.diagnostics
            res.checked += r.checked
        res.wall_s = time.perf_counter() - t0
        results.append(res)

    if "compile-bound" in which:
        t0 = time.perf_counter()
        res = compile_bound.PassResult(name="compile-bound")
        for twb in (False, True):
            # speculation ships full-width tables (the engine forbids
            # speculate_k + twb), so the twb leg audits the spec-free
            # ladder and the plain leg carries the engine's k-ladder
            sk = 0 if twb else eng.spec_k
            inv = compile_bound.enumerate_programs(
                max_len=eng.max_len, page_size=eng.page_size,
                prefill_chunk=eng.prefill_chunk,
                buckets=eng.buckets, table_width_bucketing=twb,
                speculate_k=sk)
            r = compile_bound.audit_bound(
                inv, n_buckets=len(eng.buckets),
                n_chunk_shapes=len([b for b in eng.buckets
                                    if b <= eng.prefill_chunk]),
                max_pages=eng.max_pages, table_width_bucketing=twb,
                n_spec_shapes=len(eng.spec_ladder) if sk else 0,
                name=f"{cfg.name}[twb={twb}]")
            res.diagnostics += r.diagnostics
            res.checked += r.checked
        r = compile_bound.weak_type_audit(traced)
        res.diagnostics += r.diagnostics
        res.checked += r.checked
        res.wall_s = time.perf_counter() - t0
        results.append(res)

    if "vmem" in which:
        t0 = time.perf_counter()
        res = vmem.PassResult(name="vmem")
        import jax
        for n, jx in traced:
            r = vmem.audit_vmem(jx, name=n)
            res.diagnostics += r.diagnostics
            res.checked += r.checked
        # large-K stress shape: the adder-tree K-split's whole reason
        # to exist; cross-checked against its own plan
        import jax.numpy as jnp

        from repro.core.rowwise import plan_matmul
        from repro.kernels import ops
        with runtime.use_impl("interpret"):
            m, k, n_ = 256, 16384, 512
            plan = plan_matmul(m, k, n_, dtype_bytes=4)
            jx = jax.make_jaxpr(lambda a, b: ops.matmul(a, b))(
                jnp.zeros((m, k), jnp.float32),
                jnp.zeros((k, n_), jnp.float32))
        r = vmem.crosscheck_plan(jx, plan, name=f"matmul[k={k}]")
        res.diagnostics += r.diagnostics
        res.checked += r.checked
        res.wall_s = time.perf_counter() - t0
        results.append(res)

    if "rules" in which:
        t0 = time.perf_counter()
        res = rules.PassResult(name="rules")
        for mod in RULE_MODULES:
            r = rules.audit_file(os.path.join(serve_dir, mod))
            res.diagnostics += r.diagnostics
            res.checked += r.checked
        res.wall_s = time.perf_counter() - t0
        results.append(res)
    return results


def main(argv=None) -> int:
    args = _parse_args(argv)
    if args.explain:
        from repro.analysis.report import CODES
        print(f"{args.explain}: "
              f"{CODES.get(args.explain, 'unknown code')}")
        return 0
    if args.mesh_shape > 1 and "xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        # must happen before jax initialises — which is why every jax
        # import in this module lives inside a function
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            " --xla_force_host_platform_device_count="
            f"{max(8, args.mesh_shape)}").strip()
    which = set(args.passes.split(",")) if args.passes else None
    results = run_passes(args.arch, args.mesh_shape, which)
    failed = False
    for res in results:
        print(res.summary())
        for d in res.diagnostics:
            print(f"  {d}")
        failed = failed or not res.ok
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{
                "pass": r.name, "checked": r.checked,
                "wall_s": r.wall_s, "ok": r.ok,
                "diagnostics": [str(d) for d in r.diagnostics],
            } for r in results], f, indent=2)
    print("audit:", "FAIL" if failed else
          f"OK ({sum(r.checked for r in results)} invariant sites, "
          f"{len(results)} passes)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
