"""Donation pass: prove donated buffers really alias outputs in place.

``donate_argnums`` is a *request*, not a guarantee: when XLA cannot
alias a donated input onto an output of identical shape/dtype it falls
back to copying — silently, behind a UserWarning most CI logs scroll
past. For the serving engine that failure mode doubles cache memory
(the `(R, n_pages + n_slots, ps, Hkv, hd)` pools copy every step) and
*halves* the pool a given HBM budget can hold.

Ground truth comes from the lowered MLIR: every donated input that XLA
accepted carries a ``tf.aliasing_output = N`` attribute on the
``@main`` signature. The pass lowers each jitted entry point with its
real argument shapes and checks

  * RWA201 — every donated leaf produced an aliasing attribute (count
    match; JAX's "donated buffers were not usable" warning is captured
    and attached for the diagnosis);
  * RWA202 — for each dropped donation, whether any output leaf of
    matching shape/dtype even exists (distinguishes "engine forgot the
    output" from "aliasing order mismatch");
  * RWA203 — no two donated inputs alias the same output index (a
    double consumption would corrupt one of them).

Lowering traces but never executes, so auditing the live engine's
entry points is safe: the donated cache is only *annotated*, not
consumed.
"""
from __future__ import annotations

import re
import warnings
from typing import Sequence, Tuple

import jax

from repro.analysis.report import Diagnostic, PassResult

_ALIAS_RE = re.compile(r"tf\.aliasing_output\s*=\s*(\d+)")


def _leaf_avals(tree):
    return [(x.shape, str(x.dtype)) for x in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda t: t, tree))]


def audit_donation(fn, args: Sequence, donate_argnums: Tuple[int, ...],
                   name: str = "fn") -> PassResult:
    """Audit one jitted callable against its donation contract."""
    result = PassResult(name="donation")
    donated = []
    for i in donate_argnums:
        donated.extend(_leaf_avals(args[i]))
    result.checked = len(donated)
    if not donated:
        return result

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        text = fn.lower(*args).as_text()
    dropped = [str(w.message) for w in caught
               if "donated" in str(w.message).lower()]

    aliased = _ALIAS_RE.findall(text)
    if len(aliased) < len(donated):
        detail = f" ({dropped[0]})" if dropped else ""
        result.diagnostics.append(Diagnostic(
            code="RWA201", path=name,
            message=f"{len(donated) - len(aliased)} of {len(donated)} "
                    f"donated buffer(s) lowered without an aliasing "
                    f"attribute: XLA will copy them every call"
                    f"{detail}"))
        # say whether a home for the dropped donation even exists
        out_avals = _leaf_avals(jax.eval_shape(fn, *args))
        for shape, dtype in donated:
            if (shape, dtype) not in out_avals:
                result.diagnostics.append(Diagnostic(
                    code="RWA202", path=name,
                    message=f"donated {dtype}{list(shape)} has no "
                            "shape/dtype-matching output to alias "
                            "onto"))
    dupes = {i for i in aliased if aliased.count(i) > 1}
    if dupes:
        result.diagnostics.append(Diagnostic(
            code="RWA203", path=name,
            message=f"output index(es) {sorted(dupes)} aliased by "
                    "multiple donated inputs"))
    return result
