"""Structured jaxpr traversal for the invariant auditor.

The repo's older tests asserted kernel-launch invariants by counting
substrings of ``str(jaxpr)`` — which breaks on primitive renames and
false-matches on kernel *names* containing the primitive's. These
helpers walk the equation graph itself (recursing into every sub-jaxpr:
pjit bodies, scan/while carries, cond branches, custom_jvp rules), so a
count of ``pallas_call`` eqns means actual kernel launches.
"""
from __future__ import annotations

from typing import Iterator, List

# host-callback primitives: any of these inside a serving entry point
# is a per-step host round-trip hiding in the traced graph
CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call",
})


def _as_jaxpr(jaxpr_like):
    """Accept a ClosedJaxpr, a Jaxpr, or anything carrying `.jaxpr`."""
    inner = getattr(jaxpr_like, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    return jaxpr_like


def iter_eqns(jaxpr_like) -> Iterator:
    """Yield every equation reachable from `jaxpr_like`, depth-first,
    recursing through sub-jaxprs stashed in eqn params (pjit/scan/cond/
    remat bodies and lists thereof)."""
    stack = [_as_jaxpr(jaxpr_like)]
    seen = set()
    while stack:
        j = stack.pop()
        if id(j) in seen or not hasattr(j, "eqns"):
            continue
        seen.add(id(j))
        for eqn in j.eqns:
            yield eqn
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    stack.append(sub)


def _sub_jaxprs(v) -> List:
    if hasattr(v, "eqns"):
        return [v]
    if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
        return [v.jaxpr]
    if isinstance(v, (list, tuple)):
        out = []
        for x in v:
            out.extend(_sub_jaxprs(x))
        return out
    return []


def primitive_eqns(jaxpr_like, name: str) -> List:
    return [e for e in iter_eqns(jaxpr_like) if e.primitive.name == name]


def count_primitive(jaxpr_like, name: str) -> int:
    """Structured replacement for `str(jaxpr).count(name)`."""
    return len(primitive_eqns(jaxpr_like, name))


def callback_eqns(jaxpr_like) -> List:
    return [e for e in iter_eqns(jaxpr_like)
            if e.primitive.name in CALLBACK_PRIMITIVES]


def weak_type_invars(jaxpr_like) -> List:
    """Input vars whose aval is weak_type: a Python-scalar operand that
    would compile a second program the moment a strongly-typed value of
    the same shape arrives."""
    j = _as_jaxpr(jaxpr_like)
    return [v for v in j.invars
            if getattr(v.aval, "weak_type", False)]


# -- weight-sized concatenations (decode hot-path rule) -----------------
#
# Migrated from benchmarks/decode_bench.py so tests and the AST rule
# pass share one definition; the bench re-exports it.

def weight_concat_eqns(jaxpr_like, min_bytes: int) -> List:
    """Concatenate eqns whose output is at least `min_bytes`: in a
    decode graph these are per-token weight-panel rebuilds the fused
    param layout (DESIGN.md §5) exists to eliminate."""
    hits = []
    for eqn in iter_eqns(jaxpr_like):
        if eqn.primitive.name != "concatenate":
            continue
        aval = eqn.outvars[0].aval
        size = 1
        for d in aval.shape:
            size *= int(d)
        if size * aval.dtype.itemsize >= min_bytes:
            hits.append(eqn)
    return hits


def min_weight_bytes(cfg, itemsize: int = 4) -> int:
    """Threshold separating weight-panel concats from small activation
    concats: the smallest per-layer projection panel (KV heads)."""
    return cfg.d_model * cfg.n_kv_heads * cfg.head_dim * itemsize
