"""Static invariant auditor (DESIGN.md §9).

Five passes prove the serving stack's execution contract from traced
jaxprs and source ASTs, without running anything:

  * :mod:`repro.analysis.sync` — one device fetch per step-loop phase,
    no hidden host<->device synchronisation (RWA1xx);
  * :mod:`repro.analysis.donation` — donated buffers alias outputs in
    the lowered MLIR (RWA2xx);
  * :mod:`repro.analysis.compile_bound` — closed-form enumeration of
    the reachable shape-signature set vs the documented bound (RWA3xx);
  * :mod:`repro.analysis.vmem` — per-``pallas_call`` VMEM residency vs
    the planner's budget (RWA4xx);
  * :mod:`repro.analysis.rules` — PagePool transaction discipline and
    decode-path hygiene (RWA5xx).

CLI: ``python -m repro.analysis.audit`` (gating CI tier).
"""
from repro.analysis.jaxprs import (callback_eqns, count_primitive,
                                   iter_eqns, min_weight_bytes,
                                   primitive_eqns, weak_type_invars,
                                   weight_concat_eqns)
from repro.analysis.report import CODES, Diagnostic, PassResult

__all__ = [
    "CODES", "Diagnostic", "PassResult", "callback_eqns",
    "count_primitive", "iter_eqns", "min_weight_bytes",
    "primitive_eqns", "weak_type_invars", "weight_concat_eqns",
]
