"""Diagnostics for the static invariant auditor.

Every analysis pass reports through the same two shapes:

  * :class:`Diagnostic` — one ruff-style finding, carrying a stable
    ``RWAnnn`` code, the offending location and a one-line message.
  * :class:`PassResult` — one pass run: its diagnostics plus how many
    invariant sites it actually checked (a pass that checked nothing is
    suspicious, not clean) and its wall time (BENCH_PR9 reads it).

Code families (catalogued in DESIGN.md §9):

  RWA1xx  sync-point pass       hidden host<->device synchronisation
  RWA2xx  donation pass         donated buffer not aliased in place
  RWA3xx  compile-bound pass    shape-signature set exceeds the bound
  RWA4xx  Pallas VMEM pass      kernel footprint over the VMEM budget
  RWA5xx  AST rule pass         pool-transaction / decode-path rules
"""
from __future__ import annotations

import dataclasses
from typing import List

CODES = {
    "RWA101": "`.item()` on a device value forces a blocking transfer",
    "RWA102": "int()/float()/bool() on a device value is a hidden sync",
    "RWA103": "np.asarray/np.array on a device value is a hidden sync",
    "RWA104": "device fetch count differs from the step-loop contract",
    "RWA105": "block_until_ready() outside a sanctioned fetch site",
    "RWA106": "host callback primitive inside a jitted entry point",
    "RWA201": "donated buffer is not aliased to any output (silently "
              "copied: the donation was dropped by XLA)",
    "RWA202": "donated buffer has no shape/dtype-matching output to "
              "alias onto",
    "RWA203": "two donated buffers alias the same output",
    "RWA301": "reachable shape-signature set exceeds the documented "
              "compile bound",
    "RWA302": "weak_type operand in a jitted entry point fragments the "
              "jit cache",
    "RWA303": "runtime compiled-program count disagrees with the "
              "static enumeration",
    "RWA401": "pallas kernel block+scratch residency exceeds the "
              "modeled VMEM budget",
    "RWA402": "traced kernel footprint exceeds plan_matmul's accounting",
    "RWA501": "PagePool.begin not paired with commit/rollback on a "
              "normal exit path",
    "RWA502": "eviction (_make_room/reclaim) inside an open pool "
              "transaction",
    "RWA503": "pool mutation outside a transaction in the decode path",
    "RWA504": "jnp.concatenate/stack in a decode module (weight-sized "
              "concats belong in the fused param layout)",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    code: str
    message: str
    path: str = ""                   # file, or entry-point name
    line: int = 0
    severity: str = "error"          # "error" gates; "warning" informs

    def __post_init__(self):
        assert self.code in CODES, f"unregistered diagnostic {self.code}"

    def __str__(self):
        loc = f"{self.path}:{self.line}: " if self.path else ""
        return f"{loc}{self.code} {self.message}"


@dataclasses.dataclass
class PassResult:
    name: str
    diagnostics: List[Diagnostic] = dataclasses.field(default_factory=list)
    checked: int = 0                 # invariant sites actually examined
    wall_s: float = 0.0

    @property
    def ok(self) -> bool:
        return not any(d.severity == "error" for d in self.diagnostics)

    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    def summary(self) -> str:
        state = "ok" if self.ok else f"{len(self.errors())} error(s)"
        return (f"[{self.name}] {state}: {self.checked} site(s) checked "
                f"in {self.wall_s * 1e3:.0f} ms")
