"""Row-wise matmul — the paper's dot-product primitive as a Pallas kernel.

Mapping of the paper's ASIC dataflow onto TPU (see DESIGN.md §2):

  * **Weight broadcast / weight-stationary.** The grid is ``(n_tiles,
    m_tiles, k_splits)``. For a single-panel contraction the weight
    panel's index map depends only on *n*, so consecutive *m* steps
    revisit the same weight block and Pallas keeps it resident in VMEM —
    the TPU equivalent of broadcasting one weight down all 7 PE rows.
  * **Row-wise streaming.** Activation row panels ``(bm, bk)`` stream
    past the weight panel, one per grid step, exactly like input rows
    streaming through the PE block.
  * **Accumulator / adder tree.** Contractions too large for one VMEM
    panel run over the *innermost* ``k_splits`` grid axis: each step
    multiplies a ``(bm, bk) @ (bk, bn)`` panel pair and adds it into an
    fp32 (int32 for int8) VMEM scratch accumulator. The output block's
    index map ignores the k axis, so partial sums stay on-chip for the
    whole tree — one ``pallas_call``, no HBM round-trips.
  * **Post-processing unit.** Bias + activation (+ int8 dequant) run as
    the kernel epilogue, predicated on the *final* k step only.

Supports bf16/fp32 and the paper's 8-bit W/A mode (int8 x int8 -> int32
accumulation with per-row activation scales and per-channel weight
scales, as in ``core/quant.py``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.rowwise import TilePlan, plan_matmul

_ACTIVATIONS = {
    None: lambda x: x,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def _fused_kernel(*refs, activation: Optional[str], int8: bool,
                  with_bias: bool):
    """One body for all four variants (float/int8 × bias/no-bias).

    refs: x, w, [x_scale, w_scale], [bias], out, acc_scratch. Zero the
    scratch on the first k step, accumulate a (bm, bk) @ (bk, bn) panel
    product every step (fp32, exact int32 for int8), and run the
    post-processing epilogue only on the final k step.
    """
    x_ref, w_ref = refs[:2]
    o_ref, acc_ref = refs[-2:]
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if int8:
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    else:
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _epilogue():
        out = acc_ref[...]
        if int8:
            xs_ref, ws_ref = refs[2], refs[3]
            out = out.astype(jnp.float32) * xs_ref[...] * ws_ref[...]
        if with_bias:
            out = out + refs[-3][...].astype(jnp.float32)
        o_ref[...] = _ACTIVATIONS[activation](out).astype(o_ref.dtype)


def _pad2(x, m, n):
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def rowwise_matmul_p(x: jnp.ndarray, w: jnp.ndarray, *,
                     bias: Optional[jnp.ndarray] = None,
                     x_scale: Optional[jnp.ndarray] = None,
                     w_scale: Optional[jnp.ndarray] = None,
                     activation: Optional[str] = None,
                     out_dtype=None,
                     plan: Optional[TilePlan] = None,
                     interpret: bool = False) -> jnp.ndarray:
    """One pallas_call over the whole contraction, any ``k_splits``.

    x: (M, K); w: (K, N); bias: (N,) optional.
    int8 mode when x_scale/w_scale given: x,w int8; scales fp32
    (M,1)/(1,N).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    int8_mode = x_scale is not None
    if plan is None:
        plan = plan_matmul(m, k, n, dtype_bytes=x.dtype.itemsize)
    assert k <= plan.bk * plan.k_splits
    out_dtype = out_dtype or (jnp.float32 if int8_mode else x.dtype)

    bm, bk, bn = plan.bm, plan.bk, plan.bn
    mp, np_, kp = plan.m_pad, plan.n_pad, plan.k_pad
    x = _pad2(x, mp, kp)
    w = _pad2(w, kp, np_)
    # k innermost: the output block's index map ignores ki, so Pallas
    # holds it (plus the scratch accumulator) in VMEM across the tree.
    grid = (np_ // bn, mp // bm, plan.k_splits)

    x_spec = pl.BlockSpec((bm, bk), lambda ni, mi, ki: (mi, ki))
    w_spec = pl.BlockSpec((bk, bn), lambda ni, mi, ki: (ki, ni))
    o_spec = pl.BlockSpec((bm, bn), lambda ni, mi, ki: (mi, ni))
    out_shape = jax.ShapeDtypeStruct((mp, np_), out_dtype)
    acc_dtype = jnp.int32 if int8_mode else jnp.float32
    # n/m tiles are independent; only the k axis carries the accumulator.
    params = dict(
        grid=grid, out_specs=o_spec, out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret)
    if not interpret:
        params["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    inputs = [x, w]
    in_specs = [x_spec, w_spec]
    if int8_mode:
        inputs += [_pad2(x_scale.astype(jnp.float32), mp, 1),
                   _pad2(w_scale.astype(jnp.float32), 1, np_)]
        in_specs += [pl.BlockSpec((bm, 1), lambda ni, mi, ki: (mi, 0)),
                     pl.BlockSpec((1, bn), lambda ni, mi, ki: (0, ni))]
    if bias is not None:
        inputs.append(_pad2(bias.reshape(1, -1).astype(jnp.float32),
                            1, np_))
        in_specs.append(pl.BlockSpec((1, bn), lambda ni, mi, ki: (0, ni)))

    fn = pl.pallas_call(
        functools.partial(_fused_kernel, activation=activation,
                          int8=int8_mode, with_bias=bias is not None),
        in_specs=in_specs, **params)
    return fn(*inputs)[:m, :n]
