"""Row-wise matmul — the paper's dot-product primitive as a Pallas kernel.

Mapping of the paper's ASIC dataflow onto TPU (see DESIGN.md §2–3):

  * **Weight broadcast / weight-stationary.** The grid is ``(n_tiles,
    m_tiles, k_splits)``. For a single-panel contraction the weight
    panel's index map depends only on *n*, so consecutive *m* steps
    revisit the same weight block and Pallas keeps it resident in VMEM —
    the TPU equivalent of broadcasting one weight down all 7 PE rows.
  * **Row-wise streaming.** Activation row panels ``(bm, bk)`` stream
    past the weight panel, one per grid step, exactly like input rows
    streaming through the PE block.
  * **Accumulator / adder tree.** Contractions too large for one VMEM
    panel run over the *innermost* ``k_splits`` grid axis: each step
    multiplies a ``(bm, bk) @ (bk, bn)`` panel pair and adds it into an
    fp32 (int32 for int8) VMEM scratch accumulator. The output block's
    index map ignores the k axis, so partial sums stay on-chip for the
    whole tree — one ``pallas_call``, no HBM round-trips.
  * **Post-processing unit.** Bias + activation (+ int8 dequant, gating,
    residual add) run as the kernel epilogue, predicated on the *final*
    k step only — one parameterized epilogue for every variant.
  * **Norm prologue (PR 2).** The pre-norm of a transformer sublayer
    runs on the activation row panel *inside* the kernel (fp32 stats,
    full-K panel required), so the normalized tensor never exists in
    HBM.
  * **Gated dual-weight path (PR 2).** A second weight panel streams
    next to the first, sharing the same activation rows; the epilogue
    computes ``act(x@w_gate) * (x@w)`` so SwiGLU/GeGLU's gate matmul,
    up matmul and gating multiply are one kernel.

Supports bf16/fp32 and the paper's 8-bit W/A mode (int8 x int8 -> int32
accumulation with per-row activation scales and per-channel weight
scales, as in ``core/quant.py``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.rowwise import TilePlan, plan_matmul
from repro.kernels.layernorm import rownorm

_ACTIVATIONS = {
    None: lambda x: x,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def _apply_epilogue(r, *, activation: Optional[str], int8: bool,
                    gated: bool):
    """The post-processing unit, parameterized over every variant.

    One helper replaces the four inline float/int8 x bias/no-bias code
    paths: int8 dequant -> bias -> (gating | activation) -> residual,
    all in fp32 on the accumulator block(s). ``r`` maps operand names to
    kernel refs; optional stages key off membership.
    """
    h = r["acc"][...]
    if int8:
        h = h.astype(jnp.float32) * r["x_scale"][...] * r["w_scale"][...]
    if "bias" in r:
        h = h + r["bias"][...].astype(jnp.float32)
    if gated:
        g = r["acc_g"][...]
        if int8:
            g = g.astype(jnp.float32) * r["x_scale"][...] * r["wg_scale"][...]
        if "bias_g" in r:
            g = g + r["bias_g"][...].astype(jnp.float32)
        h = _ACTIVATIONS[activation](g) * h
    else:
        h = _ACTIVATIONS[activation](h)
    if "res" in r:
        h = h + r["res"][...].astype(jnp.float32)
    return h


def _pipeline_kernel(*refs, layout, activation: Optional[str], int8: bool,
                     gated: bool, prologue: Optional[str], eps: float,
                     k_true: int):
    """One body for the whole fused pipeline.

    ``layout`` names every ref in order (inputs, then the output, then
    scratch accumulators). Zero the scratch on the first k step, run the
    optional norm prologue on the activation row panel, accumulate a
    (bm, bk) @ (bk, bn) panel product per weight every step (fp32, exact
    int32 for int8), and run the post-processing epilogue only on the
    final k step.
    """
    r = dict(zip(layout, refs))
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        r["acc"][...] = jnp.zeros_like(r["acc"])
        if gated:
            r["acc_g"][...] = jnp.zeros_like(r["acc_g"])

    x = r["x"][...]
    if prologue is not None:
        # Full-K panel per step (k_splits == 1, enforced by the
        # wrapper): fp32 stats over the true K, then back to the
        # streaming dtype so the MXU sees the same operand the unfused
        # norm->matmul composition would.
        beta = r["pbeta"][...] if "pbeta" in r else None
        x = rownorm(x, r["gamma"][...], beta, kind=prologue, eps=eps,
                    n_valid=k_true).astype(r["x"].dtype)

    if int8:
        def dot(a, b):
            return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.int32)
    else:
        def dot(a, b):
            return jnp.dot(a, b, preferred_element_type=jnp.float32)

    r["acc"][...] += dot(x, r["w"][...])
    if gated:
        r["acc_g"][...] += dot(x, r["wg"][...])

    @pl.when(ki == pl.num_programs(2) - 1)
    def _epilogue():
        out = _apply_epilogue(r, activation=activation, int8=int8,
                              gated=gated)
        r["out"][...] = out.astype(r["out"].dtype)


def _pad2(x, m, n):
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def rowwise_matmul_p(x: jnp.ndarray, w: jnp.ndarray, *,
                     bias: Optional[jnp.ndarray] = None,
                     x_scale: Optional[jnp.ndarray] = None,
                     w_scale: Optional[jnp.ndarray] = None,
                     activation: Optional[str] = None,
                     w_gate: Optional[jnp.ndarray] = None,
                     bias_gate: Optional[jnp.ndarray] = None,
                     wg_scale: Optional[jnp.ndarray] = None,
                     residual: Optional[jnp.ndarray] = None,
                     prologue: Optional[str] = None,
                     gamma: Optional[jnp.ndarray] = None,
                     pbeta: Optional[jnp.ndarray] = None,
                     eps: float = 1e-6,
                     out_dtype=None,
                     plan: Optional[TilePlan] = None,
                     interpret: bool = False) -> jnp.ndarray:
    """One pallas_call over the whole fused pipeline, any ``k_splits``.

    x: (M, K); w: (K, N); bias: (N,) optional.
    int8 mode when x_scale/w_scale given: x,w int8; scales fp32
    (M,1)/(1,N).
    w_gate: (K, N) second weight — gated mode, out = act(x@wg) * (x@w).
    residual: (M, N) added after activation/gating, before the cast.
    prologue: 'layer' | 'rms' — normalize the x row panel in-kernel
    (gamma/pbeta: (K,)); requires the plan to hold the full K in one
    panel (k_splits == 1) and a non-int8 x.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    int8_mode = x_scale is not None
    gated = w_gate is not None
    if gated:
        assert w_gate.shape == w.shape, (w_gate.shape, w.shape)
        assert not int8_mode or wg_scale is not None
    if prologue is not None:
        assert not int8_mode, "norm prologue runs on fp activations"
        assert gamma is not None
    if plan is None:
        plan = plan_matmul(m, k, n, dtype_bytes=x.dtype.itemsize,
                           n_weights=2 if gated else 1,
                           residual=residual is not None,
                           res_bytes=(residual.dtype.itemsize
                                      if residual is not None else None),
                           prologue=prologue is not None,
                           wide_n=gated or prologue is not None)
    assert k <= plan.bk * plan.k_splits
    if prologue is not None:
        assert plan.k_splits == 1 and plan.bk >= k, (
            "norm prologue needs the full K row resident per grid step; "
            "fall back to the standalone norm kernel", plan)
    out_dtype = out_dtype or (jnp.float32 if int8_mode else x.dtype)

    bm, bk, bn = plan.bm, plan.bk, plan.bn
    mp, np_, kp = plan.m_pad, plan.n_pad, plan.k_pad
    # k innermost: the output block's index map ignores ki, so Pallas
    # holds it (plus the scratch accumulators) in VMEM across the tree.
    grid = (np_ // bn, mp // bm, plan.k_splits)

    x_spec = pl.BlockSpec((bm, bk), lambda ni, mi, ki: (mi, ki))
    w_spec = pl.BlockSpec((bk, bn), lambda ni, mi, ki: (ki, ni))
    o_spec = pl.BlockSpec((bm, bn), lambda ni, mi, ki: (mi, ni))
    krow_spec = pl.BlockSpec((1, bk), lambda ni, mi, ki: (0, ki))
    nrow_spec = pl.BlockSpec((1, bn), lambda ni, mi, ki: (0, ni))

    names, inputs, in_specs = [], [], []

    def add(name, arr, spec):
        names.append(name)
        inputs.append(arr)
        in_specs.append(spec)

    add("x", _pad2(x, mp, kp), x_spec)
    if prologue is not None:
        add("gamma", _pad2(gamma.reshape(1, -1).astype(jnp.float32), 1, kp),
            krow_spec)
        if pbeta is not None:
            add("pbeta",
                _pad2(pbeta.reshape(1, -1).astype(jnp.float32), 1, kp),
                krow_spec)
    add("w", _pad2(w, kp, np_), w_spec)
    if gated:
        add("wg", _pad2(w_gate, kp, np_), w_spec)
    if int8_mode:
        add("x_scale", _pad2(x_scale.astype(jnp.float32), mp, 1),
            pl.BlockSpec((bm, 1), lambda ni, mi, ki: (mi, 0)))
        add("w_scale", _pad2(w_scale.astype(jnp.float32), 1, np_),
            nrow_spec)
        if gated:
            add("wg_scale", _pad2(wg_scale.astype(jnp.float32), 1, np_),
                nrow_spec)
    if bias is not None:
        add("bias", _pad2(bias.reshape(1, -1).astype(jnp.float32), 1, np_),
            nrow_spec)
    if gated and bias_gate is not None:
        add("bias_g",
            _pad2(bias_gate.reshape(1, -1).astype(jnp.float32), 1, np_),
            nrow_spec)
    if residual is not None:
        add("res", _pad2(residual, mp, np_), o_spec)

    acc_dtype = jnp.int32 if int8_mode else jnp.float32
    scratch = [pltpu.VMEM((bm, bn), acc_dtype)]
    layout = tuple(names) + ("out", "acc")
    if gated:
        scratch.append(pltpu.VMEM((bm, bn), acc_dtype))
        layout += ("acc_g",)

    # n/m tiles are independent; only the k axis carries the accumulator.
    params = dict(
        grid=grid, out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=scratch, interpret=interpret)
    if not interpret:
        params["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    fn = pl.pallas_call(
        functools.partial(_pipeline_kernel, layout=layout,
                          activation=activation, int8=int8_mode,
                          gated=gated, prologue=prologue, eps=eps,
                          k_true=k),
        in_specs=in_specs, **params)
    return fn(*inputs)[:m, :n]
