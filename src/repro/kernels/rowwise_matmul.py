"""Row-wise matmul — the paper's dot-product primitive as a Pallas kernel.

Mapping of the paper's ASIC dataflow onto TPU (see DESIGN.md §2):

  * **Weight broadcast / weight-stationary.** The grid is ``(n_tiles_n,
    n_tiles_m)`` with the *m* (activation-row) axis innermost. The weight
    panel's index map depends only on *n*, so consecutive grid steps
    revisit the same weight block and Pallas keeps it resident in VMEM —
    the TPU equivalent of broadcasting one weight down all 7 PE rows.
  * **Row-wise streaming.** Activation row panels ``(bm, K)`` stream past
    the stationary weight panel, one per grid step, exactly like input
    rows streaming through the PE block.
  * **Accumulator / adder tree.** The contraction runs over the whole
    VMEM-resident K panel with an fp32 (int32 for int8) accumulator;
    contractions too large for VMEM are split by the wrapper in
    ``ops.py`` and summed — the paper's adder tree for large C_in.
  * **Post-processing unit.** Bias + activation (+ int8 dequant) are
    fused as the kernel epilogue.

Supports bf16/fp32 and the paper's 8-bit W/A mode (int8 x int8 -> int32
accumulation with per-row activation scales and per-channel weight
scales, as in ``core/quant.py``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.rowwise import TilePlan, plan_matmul

_ACTIVATIONS = {
    None: lambda x: x,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def _kernel(x_ref, w_ref, o_ref, *, activation: Optional[str]):
    """Float path: (bm, K) @ (K, bn) with fp32 accumulation."""
    acc = jnp.dot(x_ref[...], w_ref[...],
                  preferred_element_type=jnp.float32)
    o_ref[...] = _ACTIVATIONS[activation](acc).astype(o_ref.dtype)


def _kernel_bias(x_ref, w_ref, b_ref, o_ref, *, activation: Optional[str]):
    acc = jnp.dot(x_ref[...], w_ref[...],
                  preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)
    o_ref[...] = _ACTIVATIONS[activation](acc).astype(o_ref.dtype)


def _kernel_int8(x_ref, w_ref, xs_ref, ws_ref, o_ref, *,
                 activation: Optional[str], with_bias: bool, b_ref=None):
    acc = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * xs_ref[...] * ws_ref[...]
    o_ref[...] = _ACTIVATIONS[activation](out).astype(o_ref.dtype)


def _kernel_int8_bias(x_ref, w_ref, xs_ref, ws_ref, b_ref, o_ref, *,
                      activation: Optional[str]):
    acc = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * xs_ref[...] * ws_ref[...]
    out = out + b_ref[...].astype(jnp.float32)
    o_ref[...] = _ACTIVATIONS[activation](out).astype(o_ref.dtype)


def _pad2(x, m, n):
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def rowwise_matmul_p(x: jnp.ndarray, w: jnp.ndarray, *,
                     bias: Optional[jnp.ndarray] = None,
                     x_scale: Optional[jnp.ndarray] = None,
                     w_scale: Optional[jnp.ndarray] = None,
                     activation: Optional[str] = None,
                     out_dtype=None,
                     plan: Optional[TilePlan] = None,
                     interpret: bool = False) -> jnp.ndarray:
    """One pallas_call over a K panel that fits VMEM (K <= plan.bk).

    x: (M, K); w: (K, N); bias: (N,) optional.
    int8 mode when x_scale/w_scale given: x,w int8; scales fp32
    (M,1)/(1,N).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    int8_mode = x_scale is not None
    if plan is None:
        plan = plan_matmul(m, k, n, dtype_bytes=x.dtype.itemsize)
    assert k <= plan.bk * plan.k_splits
    out_dtype = out_dtype or (jnp.float32 if int8_mode else x.dtype)

    bm, bn = plan.bm, plan.bn
    mp, np_, kp = plan.m_pad, plan.n_pad, plan.k_pad
    x = _pad2(x, mp, kp)
    w = _pad2(w, kp, np_)
    grid = (np_ // bn, mp // bm)  # m innermost => weight panel stationary

    x_spec = pl.BlockSpec((bm, kp), lambda ni, mi: (mi, 0))
    w_spec = pl.BlockSpec((kp, bn), lambda ni, mi: (0, ni))
    o_spec = pl.BlockSpec((bm, bn), lambda ni, mi: (mi, ni))
    out_shape = jax.ShapeDtypeStruct((mp, np_), out_dtype)

    if int8_mode:
        xs = _pad2(x_scale.astype(jnp.float32), mp, 1)
        ws = _pad2(w_scale.astype(jnp.float32), 1, np_)
        xs_spec = pl.BlockSpec((bm, 1), lambda ni, mi: (mi, 0))
        ws_spec = pl.BlockSpec((1, bn), lambda ni, mi: (0, ni))
        if bias is not None:
            b = _pad2(bias.reshape(1, -1), 1, np_)
            fn = pl.pallas_call(
                functools.partial(_kernel_int8_bias, activation=activation),
                grid=grid,
                in_specs=[x_spec, w_spec, xs_spec, ws_spec,
                          pl.BlockSpec((1, bn), lambda ni, mi: (0, ni))],
                out_specs=o_spec, out_shape=out_shape, interpret=interpret)
            out = fn(x, w, xs, ws, b)
        else:
            fn = pl.pallas_call(
                functools.partial(_kernel_int8, activation=activation,
                                  with_bias=False),
                grid=grid,
                in_specs=[x_spec, w_spec, xs_spec, ws_spec],
                out_specs=o_spec, out_shape=out_shape, interpret=interpret)
            out = fn(x, w, xs, ws)
    elif bias is not None:
        b = _pad2(bias.reshape(1, -1).astype(jnp.float32), 1, np_)
        fn = pl.pallas_call(
            functools.partial(_kernel_bias, activation=activation),
            grid=grid,
            in_specs=[x_spec, w_spec,
                      pl.BlockSpec((1, bn), lambda ni, mi: (0, ni))],
            out_specs=o_spec, out_shape=out_shape, interpret=interpret)
        out = fn(x, w, b)
    else:
        fn = pl.pallas_call(
            functools.partial(_kernel, activation=activation),
            grid=grid, in_specs=[x_spec, w_spec],
            out_specs=o_spec, out_shape=out_shape, interpret=interpret)
        out = fn(x, w)
    return out[:m, :n]
