"""Row-wise attention kernel (flash-style online softmax).

The paper executes attention on the same dot-product primitive as FC
layers: Q is broadcast as the "weight", K/V rows stream as inputs, and
softmax runs on the post-processing unit between the two matmuls. The
TPU-native version keeps that structure — one *query row panel* is held
stationary (the broadcast operand) while K/V row panels stream past it —
and fuses the softmax between the two dot products via the online
(running max / running sum) recurrence, so the S x S score matrix never
touches HBM.

Supports causal masking, sliding-window (local) attention, GQA/MQA via
an index map folding query heads onto their KV head, a kv_len bound
for padded caches, and an additive score bias (relative-position bias /
shift masks for Swin window attention): bias blocks stream into the
score loop, so the biased S x S matrix is never materialized. A bias of
shape (nb, Hq, Sq, Skv) broadcasts over the batch in cycles of ``nb``
(nb = windows-per-image for Swin's shift masks, 1 for a pure
relative-position bias). When the bias is batch-invariant (nb == 1, no
GQA), the flattened batch*head grid axis is reordered head-major so one
bias block stays VMEM-resident across the whole batch sweep instead of
being re-fetched per (batch, head).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128


def _attn_kernel(q_ref, k_ref, v_ref, *refs,
                 scale: float, causal: bool, window: int, with_bias: bool,
                 bq: int, bk: int, n_k: int, q_offset: int, kv_len: int):
    if with_bias:
        b_ref, o_ref, m_scr, l_scr, acc_scr = refs
    else:
        b_ref, (o_ref, m_scr, l_scr, acc_scr) = None, refs
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq + q_offset      # absolute position of first query row
    k_start = ki * bk

    # Block-level skip — the kernel analogue of the ASIC leaving idle PE
    # rows unclocked: skip blocks above the causal diagonal, outside the
    # sliding window, or entirely past kv_len.
    run = k_start < kv_len
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0]                      # (bq, hd)
        k = k_ref[0]                      # (bk, hd)
        v = v_ref[0]                      # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if with_bias:
            s = s + b_ref[0].astype(jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)       # (bq, bk)
        l_new = alpha * l_scr[:, :1] + jnp.sum(p, -1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == n_k - 1)
    def _fin():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_p(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: int = 0,
                      scale: Optional[float] = None,
                      bias: Optional[jnp.ndarray] = None,
                      block_q: int = 128, block_k: int = 128,
                      q_offset: int = 0,
                      interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Sq, hd); k, v: (B, Hkv, Skv, hd) -> (B, Hq, Sq, hd).

    ``q_offset``: absolute position of q[..., 0, :] (chunked prefill).
    ``bias``: (nb, Hq, Sq, Skv) additive score bias; batch index b uses
    bias row b % nb (nb must divide B).
    """
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = hd ** -0.5 if scale is None else scale

    bq, bk = min(block_q, sq), min(block_k, skv)
    sq_p, skv_p = -(-sq // bq) * bq, -(-skv // bk) * bk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))

    qf = q.reshape(b * hq, sq_p, hd)
    kf = k.reshape(b * hkv, skv_p, hd)
    vf = v.reshape(b * hkv, skv_p, hd)
    n_k = skv_p // bk
    grid = (b * hq, sq_p // bq, n_k)

    nb = 0
    if bias is not None:
        nb = bias.shape[0]
        assert bias.shape[1:] == (hq, sq, skv) and b % nb == 0, (
            bias.shape, (b, hq, sq, skv))
        if (sq_p, skv_p) != (sq, skv):
            bias = jnp.pad(bias, ((0, 0), (0, 0), (0, sq_p - sq),
                                  (0, skv_p - skv)))
        bias = bias.reshape(nb * hq, sq_p, skv_p)

    # Grid axis 0 enumerates (batch, head). With a batch-invariant bias
    # and no GQA head grouping there is no KV-panel reuse to protect, so
    # flip to head-major: the bias block's index then changes only once
    # per batch sweep and stays VMEM-resident (28 KB fetched Hq times
    # instead of B*Hq times for Swin's 49x49 windows).
    head_major = bias is not None and nb == 1 and group == 1
    if head_major:
        def qo_index(bh, qi, ki):
            return ((bh % b) * hq + bh // b, qi, 0)

        def kv_index(bh, qi, ki):
            return ((bh % b) * hkv + (bh // b) // group, ki, 0)

        def bias_index(bh, qi, ki):
            return (bh // b, qi, ki)
    else:
        def qo_index(bh, qi, ki):
            return (bh, qi, 0)

        def kv_index(bh, qi, ki):
            return ((bh // hq) * hkv + (bh % hq) // group, ki, 0)

        def bias_index(bh, qi, ki):
            return (((bh // hq) % nb) * hq + bh % hq, qi, ki)

    in_specs = [
        pl.BlockSpec((1, bq, hd), qo_index),
        pl.BlockSpec((1, bk, hd), kv_index),
        pl.BlockSpec((1, bk, hd), kv_index),
    ]
    inputs = [qf, kf, vf]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bq, bk), bias_index))
        inputs.append(bias)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        with_bias=bias is not None, bq=bq, bk=bk, n_k=n_k,
        q_offset=q_offset, kv_len=skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, hd), qo_index),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running max
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running denom
            pltpu.VMEM((bq, hd), jnp.float32),       # fp32 accumulator
        ],
        interpret=interpret,
    )(*inputs)
    return out.reshape(b, hq, sq_p, hd)[:, :, :sq, :]
