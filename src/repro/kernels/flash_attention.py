"""Row-wise attention kernel (flash-style online softmax).

The paper executes attention on the same dot-product primitive as FC
layers: Q is broadcast as the "weight", K/V rows stream as inputs, and
softmax runs on the post-processing unit between the two matmuls. The
TPU-native version keeps that structure — one *query row panel* is held
stationary (the broadcast operand) while K/V row panels stream past it —
and fuses the softmax between the two dot products via the online
(running max / running sum) recurrence, so the S x S score matrix never
touches HBM.

Supports causal masking, sliding-window (local) attention, GQA/MQA via
an index map folding query heads onto their KV head, and a kv_len bound
for padded caches.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int,
                 bq: int, bk: int, n_k: int, q_offset: int, kv_len: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq + q_offset      # absolute position of first query row
    k_start = ki * bk

    # Block-level skip — the kernel analogue of the ASIC leaving idle PE
    # rows unclocked: skip blocks above the causal diagonal, outside the
    # sliding window, or entirely past kv_len.
    run = k_start < kv_len
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(run)
    def _body():
        q = q_ref[0]                      # (bq, hd)
        k = k_ref[0]                      # (bk, hd)
        v = v_ref[0]                      # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]                              # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)       # (bq, bk)
        l_new = alpha * l_scr[:, :1] + jnp.sum(p, -1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == n_k - 1)
    def _fin():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_p(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: int = 0,
                      scale: Optional[float] = None,
                      block_q: int = 128, block_k: int = 128,
                      q_offset: int = 0,
                      interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Sq, hd); k, v: (B, Hkv, Skv, hd) -> (B, Hq, Sq, hd).

    ``q_offset``: absolute position of q[..., 0, :] (chunked prefill).
    """
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = hd ** -0.5 if scale is None else scale

    bq, bk = min(block_q, sq), min(block_k, skv)
    sq_p, skv_p = -(-sq // bq) * bq, -(-skv // bk) * bk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))

    qf = q.reshape(b * hq, sq_p, hd)
    kf = k.reshape(b * hkv, skv_p, hd)
    vf = v.reshape(b * hkv, skv_p, hd)
    n_k = skv_p // bk
    grid = (b * hq, sq_p // bq, n_k)

    def kv_index(bh, qi, ki):
        return ((bh // hq) * hkv + (bh % hq) // group, ki, 0)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_k=n_k, q_offset=q_offset, kv_len=skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running max
            pltpu.VMEM((bq, _LANES), jnp.float32),   # running denom
            pltpu.VMEM((bq, hd), jnp.float32),       # fp32 accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq_p, hd)[:, :, :sq, :]
