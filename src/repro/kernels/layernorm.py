"""Row-wise normalization kernel — the paper's post-processing unit.

LayerNorm / RMSNorm over the channel dim, one activation *row panel* per
grid step (same row-streaming structure as the matmul kernel). fp32
statistics regardless of input dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def rownorm(x, gamma, beta=None, *, kind: str, eps: float,
            n_valid: Optional[int] = None):
    """fp32 LayerNorm/RMSNorm of a (bm, D) row panel — the shared norm
    math for the standalone kernel AND the matmul kernel's fused norm
    prologue. ``n_valid`` masks a zero-padded tail of the channel dim so
    statistics are taken over the true D only (the prologue's K panel is
    lane-padded)."""
    x = x.astype(jnp.float32)
    d = x.shape[-1]
    masked = n_valid is not None and n_valid != d
    if masked:
        mask = jax.lax.broadcasted_iota(jnp.int32, x.shape,
                                        x.ndim - 1) < n_valid
        xm = jnp.where(mask, x, 0.0)
    else:
        xm = x
    denom = n_valid if masked else d
    if kind == "layer":
        mu = jnp.sum(xm, -1, keepdims=True) / denom
        xc = x - mu
        if masked:
            xc = jnp.where(mask, xc, 0.0)
    else:                                          # rms
        xc = xm
    var = jnp.sum(jnp.square(xc), -1, keepdims=True) / denom
    y = xc * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
    if beta is not None:
        y = y + beta.astype(jnp.float32)
    return y


def _norm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float, kind: str):
    y = rownorm(x_ref[...], g_ref[...],
                None if b_ref is None else b_ref[...], kind=kind, eps=eps)
    o_ref[...] = y.astype(o_ref.dtype)


def _norm_kernel_nobias(x_ref, g_ref, o_ref, *, eps: float, kind: str):
    _norm_kernel(x_ref, g_ref, None, o_ref, eps=eps, kind=kind)


def layernorm_p(x: jnp.ndarray, gamma: jnp.ndarray,
                beta: jnp.ndarray = None, *, eps: float = 1e-6,
                kind: str = "layer", block_m: int = 256,
                interpret: bool = False) -> jnp.ndarray:
    """x: (M, D); gamma/beta: (D,). kind: 'layer' | 'rms'."""
    m, d = x.shape
    bm = min(block_m, m)
    mp = -(-m // bm) * bm
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    grid = (mp // bm,)
    x_spec = pl.BlockSpec((bm, d), lambda i: (i, 0))
    g_spec = pl.BlockSpec((1, d), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((mp, d), x.dtype)
    g2 = gamma.reshape(1, d)
    if beta is not None:
        fn = pl.pallas_call(
            functools.partial(_norm_kernel, eps=eps, kind=kind),
            grid=grid, in_specs=[x_spec, g_spec, g_spec],
            out_specs=x_spec, out_shape=out_shape, interpret=interpret)
        out = fn(x, g2, beta.reshape(1, d))
    else:
        fn = pl.pallas_call(
            functools.partial(_norm_kernel_nobias, eps=eps, kind=kind),
            grid=grid, in_specs=[x_spec, g_spec],
            out_specs=x_spec, out_shape=out_shape, interpret=interpret)
        out = fn(x, g2)
    return out[:m]
