"""Row-wise normalization kernel — the paper's post-processing unit.

LayerNorm / RMSNorm over the channel dim, one activation *row panel* per
grid step (same row-streaming structure as the matmul kernel). fp32
statistics regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _norm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float, kind: str):
    x = x_ref[...].astype(jnp.float32)             # (bm, D)
    if kind == "layer":
        mu = jnp.mean(x, -1, keepdims=True)
        xc = x - mu
    else:                                          # rms
        xc = x
    var = jnp.mean(jnp.square(xc), -1, keepdims=True)
    y = xc * jax.lax.rsqrt(var + eps)
    y = y * g_ref[...].astype(jnp.float32)
    if b_ref is not None:
        y = y + b_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _norm_kernel_nobias(x_ref, g_ref, o_ref, *, eps: float, kind: str):
    _norm_kernel(x_ref, g_ref, None, o_ref, eps=eps, kind=kind)


def layernorm_p(x: jnp.ndarray, gamma: jnp.ndarray,
                beta: jnp.ndarray = None, *, eps: float = 1e-6,
                kind: str = "layer", block_m: int = 256,
                interpret: bool = False) -> jnp.ndarray:
    """x: (M, D); gamma/beta: (D,). kind: 'layer' | 'rms'."""
    m, d = x.shape
    bm = min(block_m, m)
    mp = -(-m // bm) * bm
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    grid = (mp // bm,)
    x_spec = pl.BlockSpec((bm, d), lambda i: (i, 0))
    g_spec = pl.BlockSpec((1, d), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((mp, d), x.dtype)
    g2 = gamma.reshape(1, d)
    if beta is not None:
        fn = pl.pallas_call(
            functools.partial(_norm_kernel, eps=eps, kind=kind),
            grid=grid, in_specs=[x_spec, g_spec, g_spec],
            out_specs=x_spec, out_shape=out_shape, interpret=interpret)
        out = fn(x, g2, beta.reshape(1, d))
    else:
        fn = pl.pallas_call(
            functools.partial(_norm_kernel_nobias, eps=eps, kind=kind),
            grid=grid, in_specs=[x_spec, g_spec],
            out_specs=x_spec, out_shape=out_shape, interpret=interpret)
        out = fn(x, g2)
    return out[:m]
