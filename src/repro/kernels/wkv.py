"""Pallas WKV6 kernel — the row-wise treatment of RWKV's recurrence.

EXPERIMENTS.md §Perf (rwkv6 train, iteration A2) shows the chunked-jnp
WKV is memory-bound on state flux: S (P x P) per head round-trips HBM
every 16-token chunk. This kernel keeps S resident in VMEM across the
whole sequence (the grid iterates chunks innermost per (batch x head)),
so HBM traffic drops to the r/k/v/w reads + y write — the same
structural move as the flash-attention kernel (and the paper's
keep-the-accumulator-on-chip rule, Sec. IV-D).

Chunk math matches models/rwkv6.wkv_chunked (clamped per-channel log
decays; see the numerics note there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_out_ref,
                s_scr, *, n_chunks: int, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)              # (L, P)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)            # (L, P), < 0
    u = u_ref[0].astype(jnp.float32)              # (1, P) -> broadcast

    cs = jnp.cumsum(lw, axis=0)                   # inclusive
    cs_prev = cs - lw                             # exclusive
    rd = r * jnp.exp(cs_prev)                     # (L, P)
    kd = k * jnp.exp(-cs)
    a = jax.lax.dot_general(rd, kd, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    li = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(li > lj, a, 0.0)                # strict lower triangle
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)   # (L, 1)
    y = (jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + diag * v
         + jax.lax.dot_general(rd, s_scr[...], (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)

    tail = jnp.exp(cs[-1:] - cs)                  # (L, P)
    s_scr[...] = (jnp.exp(cs[-1])[:, None] * s_scr[...]
                  + jax.lax.dot_general(tail * k, v,
                                        (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))

    @pl.when(ci == n_chunks - 1)
    def _fin():
        s_out_ref[0] = s_scr[...]


def wkv_p(r, k, v, lw, u, *, chunk: int = 16, interpret: bool = False):
    """r/k/v/lw: (B, S, H, P); u: (H, P). Returns (y (B,S,H,P),
    S_fin (B,H,P,P) fp32). S stays in VMEM across the sequence."""
    b, s, h, p = r.shape
    pad = (-s) % chunk
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        lw = jnp.pad(lw, z)                       # pad decay 0 => unused
    sp = s + pad
    nc = sp // chunk

    def bh(x):   # (B, S, H, P) -> (B*H, S, P)
        return x.transpose(0, 2, 1, 3).reshape(b * h, sp, p)

    rf, kf, vf, lwf = bh(r), bh(k), bh(v), bh(lw)
    uf = jnp.broadcast_to(u[None], (b, h, p)).reshape(b * h, 1, p)

    grid = (b * h, nc)
    seq_spec = pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0))
    u_spec = pl.BlockSpec((1, 1, p), lambda i, c: (i, 0, 0))
    y, s_fin = pl.pallas_call(
        functools.partial(_wkv_kernel, n_chunks=nc, chunk=chunk),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, u_spec],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, p, p), lambda i, c: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b * h, sp, p), r.dtype),
                   jax.ShapeDtypeStruct((b * h, p, p), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((p, p), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, uf)
    y = y.reshape(b, h, sp, p).transpose(0, 2, 1, 3)[:, :s]
    return y, s_fin.reshape(b, h, p, p)
