"""Pure-jnp oracles for every kernel. Ground truth for allclose tests."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_ACTS = {
    None: lambda x: x,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def matmul_ref(x, w, *, bias=None, activation=None, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    # fp32 accumulation WITHOUT materializing fp32 casts of the operands
    # (an .astype(f32) on FSDP-sharded weights doubles the all-gather
    # traffic and forces a full-size copy; preferred_element_type lets
    # the MXU consume bf16 directly)
    acc = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    return _ACTS[activation](acc).astype(out_dtype)


def pipeline_ref(x, w, *, bias=None, activation=None, w_gate=None,
                 bias_gate=None, residual=None, norm_kind=None,
                 gamma=None, beta=None, eps=1e-6, out_dtype=None):
    """Oracle for the fused block pipeline: optional pre-norm, one or
    two (gated) matmuls, bias/activation/gating, residual add — the
    exact composition the Pallas pipeline kernel fuses."""
    out_dtype = out_dtype or x.dtype
    if norm_kind is not None:
        x = layernorm_ref(x, gamma, beta, eps=eps, kind=norm_kind)
    h = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if bias is not None:
        h = h + bias.astype(jnp.float32)
    if w_gate is not None:
        g = jax.lax.dot_general(x, w_gate,
                                (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if bias_gate is not None:
            g = g + bias_gate.astype(jnp.float32)
        h = _ACTS[activation](g) * h
    else:
        h = _ACTS[activation](h)
    if residual is not None:
        h = h + residual.astype(jnp.float32)
    return h.astype(out_dtype)


def matmul_int8_ref(xq, wq, x_scale, w_scale, *, bias=None,
                    activation=None, out_dtype=jnp.float32):
    acc = jnp.dot(xq.astype(jnp.int32), wq.astype(jnp.int32))
    out = acc.astype(jnp.float32) * x_scale * w_scale
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return _ACTS[activation](out).astype(out_dtype)


def attention_ref(q, k, v, *, causal=True, window: int = 0,
                  scale: Optional[float] = None, q_offset: int = 0,
                  kv_len: Optional[int] = None, bias=None):
    """Dense softmax attention. q: (B,Hq,Sq,hd); k,v: (B,Hkv,Skv,hd).

    ``bias``: (nb, Hq, Sq, Skv) additive score bias, batch b uses row
    b % nb (Swin relative-position bias / shift masks).
    """
    b, hq, sq, hd = q.shape
    _, hkv, skv, _ = k.shape
    scale = hd ** -0.5 if scale is None else scale
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if bias is not None:
        nb = bias.shape[0]
        s = (s.reshape(b // nb, nb, hq, sq, skv)
             + bias[None].astype(jnp.float32)).reshape(b, hq, sq, skv)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if kv_len is not None:
        mask &= k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def layernorm_ref(x, gamma, beta=None, *, eps=1e-6, kind="layer"):
    xf = x.astype(jnp.float32)
    if kind == "layer":
        xf = xf - jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf), -1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
    if beta is not None:
        y = y + beta.astype(jnp.float32)
    return y.astype(x.dtype)


def patch_embed_ref(img, w, b=None, *, patch: int = 4):
    """img: (B, H, W, C); w: (patch*patch*C, D). Conv stride=kernel=patch."""
    bsz, h, _w, c = img.shape
    d = w.shape[1]
    k = jax.lax.conv_general_dilated(
        img.astype(jnp.float32),
        w.reshape(patch, patch, c, d).astype(jnp.float32),
        window_strides=(patch, patch), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if b is not None:
        k = k + b.astype(jnp.float32)
    return k.astype(img.dtype)
