"""Public jit'd wrappers over the Pallas kernels.

Every dense op in the framework funnels through :func:`matmul` — the
paper's "single dot-product primitive for a unified execution". The
wrapper handles leading batch dims and impl dispatch (pallas /
interpret / jnp ref); MXU padding and the adder-tree split of oversized
contractions live inside the kernel's 3-D grid, so any plan is exactly
one ``pallas_call``.

PR 2 lifts the fusion one level, from inside a matmul to *between* the
ops of a transformer sublayer (DESIGN.md §3):

  * ``matmul(norm=...)``        — pre-norm runs as the kernel prologue;
  * ``matmul(residual=...)``    — the residual add rides the epilogue;
  * :func:`qkv_proj`            — wq|wk|wv as ONE stored weight panel so
                                  one activation row fetch feeds all
                                  heads' projections (column weight
                                  sharing); outputs are sliced per
                                  projection;
  * :func:`gate_up_proj`        — the wg|wi panel streams through one
                                  kernel whose epilogue computes
                                  ``act(g) * h`` (SwiGLU/GeGLU).

PR 4 moves the fused panels into the *param tree* (DESIGN.md §5): the
multi-projection ops take a pre-concatenated weight leaf and slice
outputs, so no per-call ``jnp.concatenate`` ever materializes a
weight-sized buffer — the write that dominated decode, where M is a
handful of serving slots but the panel is the full weight matrix.
Weight leaves may also be weight-only int8 ``{"q", "s"}`` dicts
(``core.quant.quantize_tree``); they are dequantized on the fly.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp

from repro.core import quant, runtime
from repro.core.rowwise import plan_matmul
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_p
from repro.kernels.layernorm import layernorm_p
from repro.kernels.rowwise_matmul import rowwise_matmul_p


class NormSpec(NamedTuple):
    """A pre-norm to fuse into a matmul's prologue."""
    kind: str                       # 'layer' | 'rms'
    gamma: jnp.ndarray
    beta: Optional[jnp.ndarray] = None
    eps: float = 1e-6


def _flatten_leading(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _plan_norm_fallback(x2, norm, interpret, n, **plan_kw):
    """Plan the fused pipeline; if the norm prologue can't hold the full
    K in one panel (k_splits > 1), run the standalone norm kernel and
    re-plan the remaining (still fused) pipeline. Returns
    (x2, norm, plan)."""
    m, k = x2.shape
    plan = plan_matmul(m, k, n, dtype_bytes=x2.dtype.itemsize,
                       prologue=norm is not None, **plan_kw)
    if norm is not None and plan.k_splits > 1:
        x2 = layernorm_p(x2, norm.gamma, norm.beta, eps=norm.eps,
                         kind=norm.kind, interpret=interpret)
        norm = None
        plan = plan_matmul(m, k, n, dtype_bytes=x2.dtype.itemsize,
                           **plan_kw)
    return x2, norm, plan


def matmul(x: jnp.ndarray, w: jnp.ndarray, *,
           bias: Optional[jnp.ndarray] = None,
           activation: Optional[str] = None,
           residual: Optional[jnp.ndarray] = None,
           norm: Optional[NormSpec] = None,
           wide_n: Optional[bool] = None,
           impl: Optional[str] = None,
           out_dtype=None) -> jnp.ndarray:
    """x: (..., K) @ w: (K, N) -> (..., N) with fused bias/activation.

    ``norm``: pre-normalize x in the kernel prologue (falls back to the
    standalone norm kernel when K exceeds one VMEM panel).
    ``residual``: (..., N) added after the activation, in the epilogue.
    ``wide_n``: plan a single-n-tile schedule so the activation panel
    is fetched once for the whole (concatenated) N; defaults to on
    whenever a norm prologue rides along.
    """
    impl = impl or runtime.resolve_impl()
    w = quant.resolve_weight(w, x.dtype)
    x2, lead = _flatten_leading(x)
    n = w.shape[1]
    res2 = None if residual is None else residual.reshape(-1, n)
    if impl == "ref":
        out = ref.pipeline_ref(
            x2, w, bias=bias, activation=activation, residual=res2,
            norm_kind=norm.kind if norm else None,
            gamma=norm.gamma if norm else None,
            beta=norm.beta if norm else None,
            eps=norm.eps if norm else 1e-6, out_dtype=out_dtype)
        return out.reshape(*lead, n)

    interpret = impl == "interpret"
    wide = (norm is not None) if wide_n is None else wide_n
    # The plan alone decides the decomposition: oversized contractions
    # become the kernel grid's innermost k axis (in-VMEM adder tree),
    # so every shape is exactly one pallas_call (two when the norm
    # prologue must fall back to the standalone kernel).
    x2, norm, plan = _plan_norm_fallback(
        x2, norm, interpret, n, residual=res2 is not None,
        res_bytes=None if res2 is None else res2.dtype.itemsize,
        wide_n=wide)
    out = rowwise_matmul_p(
        x2, w, bias=bias, activation=activation, residual=res2,
        prologue=norm.kind if norm else None,
        gamma=norm.gamma if norm else None,
        pbeta=norm.beta if norm else None,
        eps=norm.eps if norm else 1e-6,
        out_dtype=out_dtype, plan=plan, interpret=interpret)
    return out.reshape(*lead, n)


def qkv_proj(x: jnp.ndarray, w: jnp.ndarray, splits: Sequence[int], *,
             bias: Optional[jnp.ndarray] = None,
             norm: Optional[NormSpec] = None,
             impl: Optional[str] = None):
    """Multi-output wide-N projection over a PRE-FUSED weight panel:
    ``w`` is the stored [wq | wk | wv] (or any sibling-projection) leaf
    of shape (K, sum(splits)) — one kernel launch, one activation-row
    fetch for every projection (the paper's column weight sharing
    lifted to the sublayer level), and because the panel lives fused in
    the param tree (DESIGN.md §5) there is no per-call concatenate: the
    only weight traffic is the kernel's own panel stream. Outputs are
    sliced per projection (cheap: M x split activations).

    ``bias``: optional pre-fused (sum(splits),) bias. ``w`` may be a
    weight-only int8 ``{"q", "s"}`` leaf (dequantized on the fly).
    Returns one output per entry of ``splits``.
    """
    w = quant.resolve_weight(w, x.dtype)
    assert sum(splits) == w.shape[-1], (splits, w.shape)
    out = matmul(x, w, bias=bias, norm=norm, wide_n=True, impl=impl)
    outs, off = [], 0
    for s in splits:
        outs.append(out[..., off:off + s])
        off += s
    return tuple(outs)


def gate_up_proj(x: jnp.ndarray, w: jnp.ndarray, *, activation: str,
                 bias: Optional[jnp.ndarray] = None,
                 norm: Optional[NormSpec] = None,
                 impl: Optional[str] = None) -> jnp.ndarray:
    """Gated FFN front half as ONE kernel: ``act(x@wg) * (x@wi)`` with
    optional fused pre-norm — SwiGLU/GeGLU in a single launch.

    ``w`` is the pre-fused [wg | wi] leaf of shape (K, 2F) (DESIGN.md
    §5); the kernel streams the two halves as its dual weight operands
    — both are reads of the stored panel, no per-call concatenate or
    weight-sized copy is written. ``bias``: optional pre-fused (2F,)
    bias. ``w`` may be a weight-only int8 ``{"q", "s"}`` leaf.
    """
    impl = impl or runtime.resolve_impl()
    w = quant.resolve_weight(w, x.dtype)
    f = w.shape[-1] // 2
    assert w.shape[-1] == 2 * f, w.shape
    w_gate, w_in = w[..., :f], w[..., f:]
    bias_gate = bias_in = None
    if bias is not None:
        bias_gate, bias_in = bias[..., :f], bias[..., f:]
    x2, lead = _flatten_leading(x)
    if impl == "ref":
        out = ref.pipeline_ref(
            x2, w_in, bias=bias_in, activation=activation, w_gate=w_gate,
            bias_gate=bias_gate,
            norm_kind=norm.kind if norm else None,
            gamma=norm.gamma if norm else None,
            beta=norm.beta if norm else None,
            eps=norm.eps if norm else 1e-6)
        return out.reshape(*lead, f)

    interpret = impl == "interpret"
    x2, norm, plan = _plan_norm_fallback(x2, norm, interpret, f,
                                         n_weights=2, wide_n=True)
    out = rowwise_matmul_p(
        x2, w_in, bias=bias_in, activation=activation, w_gate=w_gate,
        bias_gate=bias_gate,
        prologue=norm.kind if norm else None,
        gamma=norm.gamma if norm else None,
        pbeta=norm.beta if norm else None,
        eps=norm.eps if norm else 1e-6,
        plan=plan, interpret=interpret)
    return out.reshape(*lead, f)


def matmul_int8(xq, wq, x_scale, w_scale, *, bias=None, activation=None,
                residual=None, wide_n: bool = False,
                impl: Optional[str] = None, out_dtype=jnp.float32):
    """W8A8 path: int8 x int8 -> int32 accum -> dequant epilogue.

    Wide-N int8 works by concatenating weights AND per-channel scales
    along N (pass ``wide_n=True`` for the single-activation-fetch
    schedule); ``residual`` rides the epilogue like the fp path.
    """
    impl = impl or runtime.resolve_impl()
    x2, lead = _flatten_leading(xq)
    n = wq.shape[1]
    s2 = x_scale.reshape(-1, 1)
    res2 = None if residual is None else residual.reshape(-1, n)
    if impl == "ref":
        out = ref.matmul_int8_ref(x2, wq, s2, w_scale, bias=bias,
                                  activation=activation, out_dtype=out_dtype)
        if res2 is not None:
            out = (out.astype(jnp.float32)
                   + res2.astype(jnp.float32)).astype(out_dtype)
    else:
        m = x2.shape[0]
        plan = plan_matmul(m, x2.shape[1], n, dtype_bytes=1,
                           residual=res2 is not None,
                           res_bytes=(res2.dtype.itemsize
                                      if res2 is not None else None),
                           wide_n=wide_n)
        out = rowwise_matmul_p(x2, wq, x_scale=s2, w_scale=w_scale,
                               bias=bias, activation=activation,
                               residual=res2, out_dtype=out_dtype,
                               plan=plan, interpret=impl == "interpret")
    return out.reshape(*lead, n)


def attention(q, k, v, *, causal=True, window: int = 0, scale=None,
              q_offset: int = 0, bias=None, impl: Optional[str] = None):
    impl = impl or runtime.resolve_impl()
    if impl == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset, bias=bias)
    return flash_attention_p(q, k, v, causal=causal, window=window,
                             scale=scale, q_offset=q_offset, bias=bias,
                             interpret=impl == "interpret")


def layernorm(x, gamma, beta=None, *, eps=1e-6, kind="layer",
              impl: Optional[str] = None):
    impl = impl or runtime.resolve_impl()
    x2, lead = _flatten_leading(x)
    if impl == "ref":
        out = ref.layernorm_ref(x2, gamma, beta, eps=eps, kind=kind)
    else:
        out = layernorm_p(x2, gamma, beta, eps=eps, kind=kind,
                          interpret=impl == "interpret")
    return out.reshape(*lead, x.shape[-1])


def wkv(r, k, v, lw, u, *, s0=None, chunk: int = 16,
        impl: Optional[str] = None):
    """RWKV6 recurrence: Pallas kernel (VMEM-resident state) on TPU /
    interpret; chunked-jnp scan otherwise. Returns (y, final state)."""
    impl = impl or runtime.resolve_impl()
    if impl in ("pallas", "interpret") and s0 is None:
        from repro.kernels.wkv import wkv_p
        return wkv_p(r, k, v, lw, u, chunk=chunk,
                     interpret=impl == "interpret")
    from repro.models.rwkv6 import wkv_chunked
    return wkv_chunked(r, k, v, lw, u, chunk=chunk, s0=s0)


def patch_embed(img, w, b=None, *, patch: int = 4,
                impl: Optional[str] = None):
    """4x4/stride-4 conv as space-to-depth + the SAME matmul primitive —
    the paper's unification of conv onto the dot-product PE (Sec. IV-C)."""
    bsz, h, wd, c = img.shape
    gh, gw = h // patch, wd // patch
    x = img.reshape(bsz, gh, patch, gw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(bsz, gh, gw,
                                              patch * patch * c)
    return matmul(x, w, bias=b, impl=impl)
