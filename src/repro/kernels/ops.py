"""Public jit'd wrappers over the Pallas kernels.

Every dense op in the framework funnels through :func:`matmul` — the
paper's "single dot-product primitive for a unified execution". The
wrapper handles leading batch dims and impl dispatch (pallas /
interpret / jnp ref); MXU padding and the adder-tree split of oversized
contractions live inside the kernel's 3-D grid, so any plan is exactly
one ``pallas_call``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import runtime
from repro.core.rowwise import plan_matmul
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_p
from repro.kernels.layernorm import layernorm_p
from repro.kernels.rowwise_matmul import rowwise_matmul_p


def _flatten_leading(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def matmul(x: jnp.ndarray, w: jnp.ndarray, *,
           bias: Optional[jnp.ndarray] = None,
           activation: Optional[str] = None,
           impl: Optional[str] = None,
           out_dtype=None) -> jnp.ndarray:
    """x: (..., K) @ w: (K, N) -> (..., N) with fused bias/activation."""
    impl = impl or runtime.resolve_impl()
    x2, lead = _flatten_leading(x)
    if impl == "ref":
        out = ref.matmul_ref(x2, w, bias=bias, activation=activation,
                             out_dtype=out_dtype)
        return out.reshape(*lead, w.shape[1])

    interpret = impl == "interpret"
    m, k = x2.shape
    n = w.shape[1]
    # The plan alone decides the decomposition: oversized contractions
    # become the kernel grid's innermost k axis (in-VMEM adder tree),
    # so every shape is exactly one pallas_call.
    plan = plan_matmul(m, k, n, dtype_bytes=x2.dtype.itemsize)
    out = rowwise_matmul_p(x2, w, bias=bias, activation=activation,
                           out_dtype=out_dtype, plan=plan,
                           interpret=interpret)
    return out.reshape(*lead, n)


def matmul_int8(xq, wq, x_scale, w_scale, *, bias=None, activation=None,
                impl: Optional[str] = None, out_dtype=jnp.float32):
    """W8A8 path: int8 x int8 -> int32 accum -> dequant epilogue."""
    impl = impl or runtime.resolve_impl()
    x2, lead = _flatten_leading(xq)
    s2 = x_scale.reshape(-1, 1)
    if impl == "ref":
        out = ref.matmul_int8_ref(x2, wq, s2, w_scale, bias=bias,
                                  activation=activation, out_dtype=out_dtype)
    else:
        out = rowwise_matmul_p(x2, wq, x_scale=s2, w_scale=w_scale,
                               bias=bias, activation=activation,
                               out_dtype=out_dtype,
                               interpret=impl == "interpret")
    return out.reshape(*lead, wq.shape[1])


def attention(q, k, v, *, causal=True, window: int = 0, scale=None,
              q_offset: int = 0, impl: Optional[str] = None):
    impl = impl or runtime.resolve_impl()
    if impl == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset)
    return flash_attention_p(q, k, v, causal=causal, window=window,
                             scale=scale, q_offset=q_offset,
                             interpret=impl == "interpret")


def layernorm(x, gamma, beta=None, *, eps=1e-6, kind="layer",
              impl: Optional[str] = None):
    impl = impl or runtime.resolve_impl()
    x2, lead = _flatten_leading(x)
    if impl == "ref":
        out = ref.layernorm_ref(x2, gamma, beta, eps=eps, kind=kind)
    else:
        out = layernorm_p(x2, gamma, beta, eps=eps, kind=kind,
                          interpret=impl == "interpret")
    return out.reshape(*lead, x.shape[-1])


def wkv(r, k, v, lw, u, *, s0=None, chunk: int = 16,
        impl: Optional[str] = None):
    """RWKV6 recurrence: Pallas kernel (VMEM-resident state) on TPU /
    interpret; chunked-jnp scan otherwise. Returns (y, final state)."""
    impl = impl or runtime.resolve_impl()
    if impl in ("pallas", "interpret") and s0 is None:
        from repro.kernels.wkv import wkv_p
        return wkv_p(r, k, v, lw, u, chunk=chunk,
                     interpret=impl == "interpret")
    from repro.models.rwkv6 import wkv_chunked
    return wkv_chunked(r, k, v, lw, u, chunk=chunk, s0=s0)


def patch_embed(img, w, b=None, *, patch: int = 4,
                impl: Optional[str] = None):
    """4x4/stride-4 conv as space-to-depth + the SAME matmul primitive —
    the paper's unification of conv onto the dot-product PE (Sec. IV-C)."""
    bsz, h, wd, c = img.shape
    gh, gw = h // patch, wd // patch
    x = img.reshape(bsz, gh, patch, gw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(bsz, gh, gw,
                                              patch * patch * c)
    out = matmul(x, w, bias=b, impl=impl)
    return out
